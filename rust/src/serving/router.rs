//! The distributed-serving **front-end router**: shards streaming
//! sessions across a pool of [`WorkerServer`](super::worker::WorkerServer)
//! processes and survives losing any of them (`mediapipe route
//! --workers a,b,c` — serving module docs, "Distributed serving").
//!
//! Placement is a **stable session shard**: a session id hashes
//! (splitmix64) to a preferred worker index, scanning forward to the
//! first healthy one. The same session therefore always lands on the
//! same worker while the pool is stable — which is what makes
//! per-session timestamp monotonicity enforceable at the worker — and
//! only moves when its worker dies.
//!
//! Failure handling, in order of detection:
//!
//! * the **reader thread** on each worker connection sees the socket
//!   die (EOF, reset, or a severed [`kill`](super::worker::WorkerServer::kill))
//!   and marks the worker down;
//! * marking a worker down **resolves every in-flight request** on that
//!   connection — callers get an answer, never a hang — and **reroutes
//!   every session** assigned to the dead worker to a healthy one
//!   (`workers_lost` / `sessions_rerouted` metrics are the test
//!   evidence). Within [`RouterConfig::retry_budget`], an in-flight
//!   request is transparently **resubmitted** on its session's rerouted
//!   worker instead of failing (`requests_retried` counts these);
//!   resubmission is safe because the reply is *known-absent* — replies
//!   ride the dead connection, and the worker drops a reply whose
//!   connection died — so the caller can never see two answers. A
//!   request whose budget is exhausted fails with a typed
//!   [`MpError::WorkerLost`];
//! * a rerouted session keeps its timestamp watermark: worker-side
//!   session state is per-connection, so the new worker accepts the
//!   continuing timestamps fresh;
//! * the **health thread** pings live workers every interval and
//!   probes dead ones. Worker pongs share the worker's single writer
//!   channel with reply frames, so under load a pong can legitimately
//!   queue behind large replies — only
//!   [`RouterConfig::health_misses`] consecutive unanswered intervals
//!   count as death. A dead worker is re-admitted only after
//!   [`RouterConfig::health_passes`] consecutive successful probes, so
//!   a flapping worker cannot bounce sessions.
//!
//! Submissions never block on a dead worker: a write failure marks the
//! worker down and retries once on the session's (now rerouted) worker;
//! with no healthy worker at all the request resolves immediately with
//! a typed error through its reply channel.

use std::collections::HashMap;
use std::io::Write;
use std::net::{Shutdown, TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicBool, AtomicU32, AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::{Duration, Instant};

use crate::error::{MpError, MpResult};
use crate::metrics::Counter;
use crate::perception::{Detections, ImageFrame};
use crate::serving::payload::ServingPayload;
use crate::serving::wire::{
    handshake, payload_encoded_len, read_frame, write_frame, Frame, WireRequest, MAX_FRAME_LEN,
    NO_DEADLINE, REQUEST_OVERHEAD,
};
use crate::sync::lock_recover;

/// Router configuration (see module docs).
#[derive(Clone, Debug)]
pub struct RouterConfig {
    /// Worker addresses (`host:port`). Order defines shard indices.
    pub workers: Vec<String>,
    /// How often live workers are pinged and dead ones probed.
    pub health_interval: Duration,
    /// Consecutive successful probes before a dead worker is
    /// re-admitted (anti-flap hysteresis).
    pub health_passes: u32,
    /// Consecutive health intervals an outstanding ping may go
    /// unanswered before the worker is declared dead. Pongs ride the
    /// worker's single writer channel behind reply frames, so one slow
    /// interval under load is expected; `1` restores mark-down on the
    /// first miss.
    pub health_misses: u32,
    /// Per-attempt TCP connect budget.
    pub connect_timeout: Duration,
    /// Deadline budget stamped on every forwarded request (`None` =
    /// no deadline). Crosses the wire as remaining budget and is
    /// re-anchored at the worker.
    pub request_deadline: Option<Duration>,
    /// How many times an in-flight request lost to a dying worker is
    /// transparently resubmitted on its session's rerouted worker
    /// before failing with [`MpError::WorkerLost`] (module docs on why
    /// resubmission never duplicates an answer). `0` restores
    /// fail-fast; capped at 8 — each retry retains a payload copy, and
    /// a budget beyond the worker pool's size buys nothing.
    pub retry_budget: u32,
}

impl RouterConfig {
    pub fn new(workers: Vec<String>) -> Self {
        RouterConfig {
            workers,
            health_interval: Duration::from_millis(50),
            health_passes: 2,
            health_misses: 3,
            connect_timeout: Duration::from_millis(500),
            request_deadline: None,
            retry_budget: 1,
        }
    }
}

/// Router-level counters; per-worker goodput lives on the slots and is
/// folded into [`Router::report`].
#[derive(Default, Debug)]
pub struct RouterMetrics {
    /// Requests successfully written to a worker.
    pub requests: Counter,
    /// Times a worker transitioned healthy → dead.
    pub workers_lost: Counter,
    /// Sessions reassigned off a dead worker.
    pub sessions_rerouted: Counter,
    /// Times a dead worker passed enough probes to rejoin.
    pub workers_readmitted: Counter,
    /// In-flight requests resubmitted on a rerouted session within
    /// [`RouterConfig::retry_budget`] instead of failing.
    pub requests_retried: Counter,
}

/// Where a reply lands: the typed-payload channel, or the detector-era
/// compat channel ([`Router::submit`]), which narrows the payload to
/// detections on delivery.
enum ReplySink {
    Payload(mpsc::Sender<MpResult<ServingPayload>>),
    Dets(mpsc::Sender<MpResult<Detections>>),
}

impl ReplySink {
    fn send(&self, result: MpResult<ServingPayload>) {
        match self {
            ReplySink::Payload(tx) => {
                let _ = tx.send(result);
            }
            ReplySink::Dets(tx) => {
                let _ = tx.send(result.and_then(ServingPayload::into_detections));
            }
        }
    }
}

/// One in-flight request's reply slot, plus what resubmission needs.
struct Pending {
    sink: ReplySink,
    session: u64,
    /// Wire timestamp of this attempt — the resubmission sort key that
    /// keeps a session's retried requests in their original order.
    timestamp: i64,
    /// A retained copy of the payload while `retries_left > 0`
    /// (`None` once the budget is spent — no point holding a possibly
    /// large payload that can never be resubmitted).
    payload: Option<ServingPayload>,
    retries_left: u32,
}

/// A live connection to one worker: single writer, reader-owned
/// pending map, ping/pong bookkeeping for the health thread.
struct Conn {
    writer: Mutex<TcpStream>,
    pending: Mutex<HashMap<u64, Pending>>,
    last_ping: AtomicU64,
    last_pong: AtomicU64,
    /// Consecutive health intervals the outstanding ping has gone
    /// unanswered (health thread only; reset when the pong lands).
    missed: AtomicU32,
}

enum SlotState {
    Up(Arc<Conn>),
    Down { passes: u32 },
}

struct WorkerSlot {
    addr: String,
    state: Mutex<SlotState>,
    /// Requests this worker answered successfully (per-worker goodput).
    goodput: Counter,
}

struct SessionState {
    worker: usize,
    /// The session's next timestamp. The mutex is the session's wire
    /// **ordering guard**: a submitter holds it from timestamp
    /// assignment through the socket write, so two threads submitting
    /// on one session hit the wire in timestamp order — otherwise the
    /// worker's watermark rejects the straggler with a spurious
    /// `TimestampViolation`. (The local path holds the session lock
    /// across its push for the same reason.)
    order: Arc<Mutex<i64>>,
}

struct RouterShared {
    cfg: RouterConfig,
    workers: Vec<WorkerSlot>,
    sessions: Mutex<HashMap<u64, SessionState>>,
    next_id: AtomicU64,
    next_nonce: AtomicU64,
    stop: AtomicBool,
    metrics: RouterMetrics,
}

/// The session-sharding front end (module docs).
pub struct Router {
    shared: Arc<RouterShared>,
    health: Option<std::thread::JoinHandle<()>>,
}

/// splitmix64 finalizer — a stable, well-mixed shard hash with no
/// dependence on `std::hash` internals (which may vary per process).
fn shard_hash(mut x: u64) -> u64 {
    x = x.wrapping_add(0x9E37_79B9_7F4A_7C15);
    x = (x ^ (x >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    x = (x ^ (x >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    x ^ (x >> 31)
}

impl Router {
    /// Connect to the configured workers and start the health thread.
    /// Workers that are unreachable at start are marked dead and picked
    /// up by the health checker once they appear.
    pub fn start(cfg: RouterConfig) -> MpResult<Router> {
        if cfg.workers.is_empty() {
            return Err(MpError::Validation("router: no workers configured".into()));
        }
        if cfg.health_passes == 0 {
            return Err(MpError::Validation(
                "router: health_passes must be >= 1".into(),
            ));
        }
        if cfg.health_misses == 0 {
            return Err(MpError::Validation(
                "router: health_misses must be >= 1".into(),
            ));
        }
        if cfg.retry_budget > 8 {
            return Err(MpError::Validation(format!(
                "router: retry_budget {} exceeds the cap of 8",
                cfg.retry_budget
            )));
        }
        let workers = cfg
            .workers
            .iter()
            .map(|addr| WorkerSlot {
                addr: addr.clone(),
                state: Mutex::new(SlotState::Down { passes: 0 }),
                goodput: Counter::default(),
            })
            .collect();
        let shared = Arc::new(RouterShared {
            cfg,
            workers,
            sessions: Mutex::new(HashMap::new()),
            next_id: AtomicU64::new(1),
            next_nonce: AtomicU64::new(1),
            stop: AtomicBool::new(false),
            metrics: RouterMetrics::default(),
        });
        for idx in 0..shared.workers.len() {
            // Best effort: a worker that is down at start is just Down.
            let _ = establish(&shared, idx);
        }
        let health_shared = Arc::clone(&shared);
        let health = std::thread::Builder::new()
            .name("mp-router-health".into())
            .spawn(move || health_main(&health_shared))
            .map_err(|e| MpError::Runtime(format!("spawn router health: {e}")))?;
        Ok(Router {
            shared,
            health: Some(health),
        })
    }

    /// Submit one typed payload on a streaming session. Always returns
    /// a receiver that resolves — with the graph's typed payload, a
    /// typed error from the worker ([`MpError::Overloaded`],
    /// [`MpError::DeadlineExceeded`], [`MpError::TimestampViolation`],
    /// [`MpError::PacketTypeMismatch`]), a typed [`MpError::WorkerLost`]
    /// if the session's worker dies with the request in flight and the
    /// retry budget is spent, or a routing error if no worker is
    /// healthy. Never hangs.
    pub fn submit_payload(
        &self,
        session: u64,
        payload: ServingPayload,
    ) -> mpsc::Receiver<MpResult<ServingPayload>> {
        let (tx, rx) = mpsc::channel();
        self.shared.submit_inner(
            session,
            payload,
            ReplySink::Payload(tx),
            self.shared.cfg.retry_budget,
        );
        rx
    }

    /// Detector-era compat shim over [`Router::submit_payload`]: submit
    /// one frame, receive detections. A non-detection reply payload
    /// resolves as a typed [`MpError::PacketTypeMismatch`].
    pub fn submit(
        &self,
        session: u64,
        frame: &ImageFrame,
    ) -> mpsc::Receiver<MpResult<Detections>> {
        let (tx, rx) = mpsc::channel();
        self.shared.submit_inner(
            session,
            ServingPayload::Frame(frame.clone()),
            ReplySink::Dets(tx),
            self.shared.cfg.retry_budget,
        );
        rx
    }

    pub fn metrics(&self) -> &RouterMetrics {
        &self.shared.metrics
    }

    /// Per-worker goodput, in config order: `(addr, answered_ok)`.
    pub fn goodput(&self) -> Vec<(String, u64)> {
        self.shared
            .workers
            .iter()
            .map(|w| (w.addr.clone(), w.goodput.get()))
            .collect()
    }

    /// Is worker `idx` currently considered healthy?
    pub fn worker_is_up(&self, idx: usize) -> bool {
        self.shared.is_up(idx)
    }

    /// Poll until worker `idx` is healthy or `timeout` elapses; returns
    /// whether it came up. (Bounded-wait helper for tests and drains.)
    pub fn wait_worker_up(&self, idx: usize, timeout: Duration) -> bool {
        let start = Instant::now();
        loop {
            if self.shared.is_up(idx) {
                return true;
            }
            if start.elapsed() > timeout {
                return false;
            }
            std::thread::sleep(Duration::from_millis(5));
        }
    }

    /// Human-readable metrics text (mirrors `ServerMetrics::report`).
    pub fn report(&self) -> String {
        let m = &self.shared.metrics;
        let mut out = String::new();
        out.push_str("router metrics\n");
        out.push_str(&format!("  requests            {}\n", m.requests.get()));
        out.push_str(&format!("  workers_lost        {}\n", m.workers_lost.get()));
        out.push_str(&format!(
            "  sessions_rerouted   {}\n",
            m.sessions_rerouted.get()
        ));
        out.push_str(&format!(
            "  workers_readmitted  {}\n",
            m.workers_readmitted.get()
        ));
        out.push_str(&format!(
            "  requests_retried    {}\n",
            m.requests_retried.get()
        ));
        for (idx, w) in self.shared.workers.iter().enumerate() {
            let up = if self.shared.is_up(idx) { "up" } else { "down" };
            out.push_str(&format!(
                "  worker[{idx}] {addr:<21} {up:<4} goodput {good}\n",
                addr = w.addr,
                good = w.goodput.get()
            ));
        }
        out
    }

    /// Stop the health thread and close every worker connection. (Also
    /// runs on drop.)
    pub fn stop(&mut self) {
        if self.shared.stop.swap(true, Ordering::AcqRel) {
            return;
        }
        if let Some(t) = self.health.take() {
            let _ = t.join();
        }
        for slot in &self.shared.workers {
            let state = lock_recover(&slot.state);
            if let SlotState::Up(conn) = &*state {
                let _ = write_frame(
                    &mut *lock_recover(&conn.writer),
                    &Frame::Goodbye {
                        reason: "router shutdown".into(),
                    },
                );
                let _ = lock_recover(&conn.writer).shutdown(Shutdown::Both);
            }
        }
    }
}

impl Drop for Router {
    fn drop(&mut self) {
        self.stop();
    }
}

impl RouterShared {
    fn is_up(&self, idx: usize) -> bool {
        matches!(&*lock_recover(&self.workers[idx].state), SlotState::Up(_))
    }

    fn up_conn(&self, idx: usize) -> Option<Arc<Conn>> {
        match &*lock_recover(&self.workers[idx].state) {
            SlotState::Up(conn) => Some(Arc::clone(conn)),
            SlotState::Down { .. } => None,
        }
    }

    /// First healthy worker scanning forward from the session's
    /// preferred shard; `None` when the whole pool is dead.
    fn first_healthy(&self, session: u64) -> Option<usize> {
        let n = self.workers.len();
        let start = (shard_hash(session) % n as u64) as usize;
        (0..n).map(|i| (start + i) % n).find(|&idx| self.is_up(idx))
    }

    /// Resolve everything in flight on `conn` (resubmitting what the
    /// retry budget allows, failing the rest with `WorkerLost`), flip
    /// the slot Down, and reroute the dead worker's sessions.
    /// Idempotent per connection: only the caller holding the
    /// currently-installed `conn` performs the transition.
    fn mark_down(&self, idx: usize, conn: &Arc<Conn>) {
        {
            let mut state = lock_recover(&self.workers[idx].state);
            match &*state {
                SlotState::Up(cur) if Arc::ptr_eq(cur, conn) => {
                    *state = SlotState::Down { passes: 0 };
                }
                // Someone else already transitioned this connection (or
                // a newer one is installed): nothing to do.
                _ => return,
            }
        }
        self.metrics.workers_lost.inc();
        let addr = self.workers[idx].addr.clone();
        let drained: Vec<Pending> = {
            let mut map = lock_recover(&conn.pending);
            map.drain().map(|(_, p)| p).collect()
        };
        // Partition the in-flight requests: a retained payload with
        // budget left is resubmitted below (the reply is known-absent —
        // it rode this dead connection — so the caller cannot see two
        // answers); the rest fail typed.
        let mut retry = Vec::new();
        for p in drained {
            if p.retries_left > 0 && p.payload.is_some() {
                retry.push(p);
            } else {
                p.sink.send(Err(MpError::WorkerLost {
                    worker: addr.clone(),
                }));
            }
        }
        // Reroute the dead worker's sessions to healthy peers. The
        // watermark (the `order` counter) travels with the session:
        // worker-side
        // session state is per-connection, so the new worker accepts
        // the continuing timestamps.
        {
            let mut sessions = lock_recover(&self.sessions);
            for (sid, st) in sessions.iter_mut() {
                if st.worker == idx {
                    if let Some(new_idx) = self.first_healthy(*sid) {
                        st.worker = new_idx;
                        self.metrics.sessions_rerouted.inc();
                    }
                }
            }
        }
        // Resubmit in original wire order (timestamps are per-session,
        // so sorting on (session, timestamp) preserves each session's
        // ordering). Each resubmission draws a *fresh* timestamp under
        // the session's order guard — concurrent submitters may already
        // have written later timestamps to the rerouted worker, and the
        // watermark only needs monotonicity, not density.
        retry.sort_by_key(|p| (p.session, p.timestamp));
        for p in retry {
            if let Some(payload) = p.payload {
                self.metrics.requests_retried.inc();
                self.submit_inner(p.session, payload, p.sink, p.retries_left - 1);
            }
        }
    }

    fn submit_inner(
        &self,
        session: u64,
        payload: ServingPayload,
        sink: ReplySink,
        retries_left: u32,
    ) {
        // A body beyond the wire cap would cross the socket only to
        // have the worker's codec reject the declared length and sever
        // the connection — failing every in-flight request on it and
        // rerouting all its sessions for one bad submission. Resolve
        // the oversized payload here, typed, without touching any
        // worker.
        let encoded = REQUEST_OVERHEAD + payload_encoded_len(&payload);
        if encoded > MAX_FRAME_LEN {
            sink.send(Err(MpError::Validation(format!(
                "router: {} payload encodes to {encoded} bytes; a request \
                 body is capped at {MAX_FRAME_LEN} — shrink the payload \
                 before submitting",
                payload.summary()
            ))));
            return;
        }
        let deadline_us = match self.cfg.request_deadline {
            Some(d) => d.as_micros().min(u128::from(u64::MAX)) as u64,
            None => NO_DEADLINE,
        };
        // One reroute retry: a write failure marks the worker down
        // (rerouting the session), then the second attempt goes to the
        // session's new worker. This is distinct from the retry budget,
        // which governs resubmission of *written* requests at
        // mark_down — a failed write provably never reached the worker,
        // so retrying it here is unconditionally safe.
        let mut sink = sink;
        for _attempt in 0..2 {
            let (idx, order) = {
                let mut sessions = lock_recover(&self.sessions);
                let entry = match sessions.get_mut(&session) {
                    Some(e) => e,
                    None => match self.first_healthy(session) {
                        Some(idx) => {
                            sessions.insert(
                                session,
                                SessionState {
                                    worker: idx,
                                    order: Arc::new(Mutex::new(0)),
                                },
                            );
                            sessions.get_mut(&session).expect("just inserted")
                        }
                        None => {
                            sink.send(Err(MpError::Runtime(
                                "router: no healthy workers".into(),
                            )));
                            return;
                        }
                    },
                };
                if !self.is_up(entry.worker) {
                    match self.first_healthy(session) {
                        Some(idx) => {
                            if idx != entry.worker {
                                entry.worker = idx;
                                self.metrics.sessions_rerouted.inc();
                            }
                        }
                        None => {
                            sink.send(Err(MpError::Runtime(
                                "router: no healthy workers".into(),
                            )));
                            return;
                        }
                    }
                }
                (entry.worker, Arc::clone(&entry.order))
            };
            let conn = match self.up_conn(idx) {
                Some(c) => c,
                None => continue, // raced with mark_down; re-resolve
            };
            let id = self.next_id.fetch_add(1, Ordering::Relaxed);
            // Retain a payload copy only while the budget allows a
            // resubmission to use it.
            let retained = if retries_left > 0 {
                Some(payload.clone())
            } else {
                None
            };
            let mut req = WireRequest {
                id,
                session,
                timestamp: 0, // assigned under the order guard below
                deadline_us,
                payload: payload.clone(),
            };
            // The order guard spans timestamp assignment AND the write
            // (see `SessionState::order`). A timestamp consumed by a
            // failed write is simply skipped — the watermark only needs
            // monotonicity, not density.
            let wrote = {
                let mut next_ts = lock_recover(&order);
                req.timestamp = *next_ts;
                *next_ts += 1;
                lock_recover(&conn.pending).insert(
                    id,
                    Pending {
                        sink,
                        session,
                        timestamp: req.timestamp,
                        payload: retained,
                        retries_left,
                    },
                );
                let mut w = lock_recover(&conn.writer);
                write_frame(&mut *w, &Frame::Request(req))
                    .and_then(|()| w.flush().map_err(MpError::from))
            };
            match wrote {
                Ok(()) => {
                    // A write into a dying socket can still "succeed"
                    // (buffered) after mark_down drained `pending` —
                    // which would orphan this request. If the
                    // connection is no longer the installed one, any
                    // entry still in the map missed the drain: pull it
                    // back and retry. (If it's gone, the drain caught
                    // it — the caller already has WorkerLost, or the
                    // resubmission owns it now.)
                    let still_installed = match &*lock_recover(&self.workers[idx].state) {
                        SlotState::Up(cur) => Arc::ptr_eq(cur, &conn),
                        SlotState::Down { .. } => false,
                    };
                    if !still_installed {
                        match lock_recover(&conn.pending).remove(&id) {
                            Some(p) => {
                                sink = p.sink;
                                continue;
                            }
                            None => return,
                        }
                    }
                    self.metrics.requests.inc();
                    return;
                }
                Err(_) => {
                    // Reclaim the slot before mark_down so the drain
                    // cannot also resolve it (a failed write provably
                    // never reached the worker — resubmitting it from
                    // the drain would be fine, but resolving it twice
                    // would not).
                    match lock_recover(&conn.pending).remove(&id) {
                        Some(p) => sink = p.sink,
                        None => {
                            // mark_down's drain beat us to it: the
                            // request is already failed or resubmitted.
                            self.mark_down(idx, &conn);
                            return;
                        }
                    }
                    self.mark_down(idx, &conn);
                    // fall through to the retry
                }
            }
        }
        sink.send(Err(MpError::Runtime("router: no healthy workers".into())));
    }
}

/// Open a connection to worker `idx`, spawn its reader, and flip the
/// slot Up. Returns the error if the worker is unreachable (the slot
/// stays Down). Takes the owning `Arc` because the reader thread needs
/// its own handle back into the router.
fn establish(shared: &Arc<RouterShared>, idx: usize) -> MpResult<()> {
    let addr = &shared.workers[idx].addr;
    let mut stream = connect(addr, shared.cfg.connect_timeout)?;
    handshake(&mut stream)?;
    let _ = stream.set_nodelay(true);
    let read_half = stream
        .try_clone()
        .map_err(|e| MpError::Io(format!("router: clone {addr}: {e}")))?;
    let conn = Arc::new(Conn {
        writer: Mutex::new(stream),
        pending: Mutex::new(HashMap::new()),
        last_ping: AtomicU64::new(0),
        last_pong: AtomicU64::new(0),
        missed: AtomicU32::new(0),
    });
    // Install before spawning the reader: if the connection dies
    // instantly, the reader's mark_down must find this conn installed
    // (otherwise its transition would be a no-op and the slot would
    // stay Up with nobody reading it until the next missed pong).
    *lock_recover(&shared.workers[idx].state) = SlotState::Up(Arc::clone(&conn));
    if let Err(e) = spawn_reader(Arc::clone(shared), idx, Arc::clone(&conn), read_half) {
        *lock_recover(&shared.workers[idx].state) = SlotState::Down { passes: 0 };
        return Err(e);
    }
    Ok(())
}

fn spawn_reader(
    shared: Arc<RouterShared>,
    idx: usize,
    conn: Arc<Conn>,
    mut read_half: TcpStream,
) -> MpResult<()> {
    std::thread::Builder::new()
        .name("mp-router-read".into())
        .spawn(move || {
            loop {
                let frame = match read_frame(&mut read_half) {
                    Ok(f) => f,
                    Err(_) => break,
                };
                match frame {
                    Frame::Reply(reply) => {
                        let pending = lock_recover(&conn.pending).remove(&reply.id);
                        if let Some(p) = pending {
                            if reply.result.is_ok() {
                                shared.workers[idx].goodput.inc();
                            }
                            p.sink.send(reply.result);
                        }
                    }
                    Frame::HealthPong { nonce, .. } => {
                        conn.last_pong.store(nonce, Ordering::Release);
                    }
                    Frame::Goodbye { .. } => break,
                    _ => {}
                }
            }
            shared.mark_down(idx, &conn);
        })
        .map_err(|e| MpError::Runtime(format!("spawn router reader: {e}")))?;
    Ok(())
}

fn connect(addr: &str, timeout: Duration) -> MpResult<TcpStream> {
    let sa = addr
        .to_socket_addrs()
        .map_err(|e| MpError::Io(format!("router: resolve {addr}: {e}")))?
        .next()
        .ok_or_else(|| MpError::Io(format!("router: resolve {addr}: no address")))?;
    TcpStream::connect_timeout(&sa, timeout)
        .map_err(|e| MpError::Io(format!("router: connect {addr}: {e}")))
}

fn health_main(shared: &Arc<RouterShared>) {
    while !shared.stop.load(Ordering::Acquire) {
        std::thread::sleep(shared.cfg.health_interval);
        if shared.stop.load(Ordering::Acquire) {
            return;
        }
        for idx in 0..shared.workers.len() {
            let up = shared.up_conn(idx);
            match up {
                Some(conn) => {
                    // An outstanding ping without its pong could mean
                    // the worker (or path) is gone — but pongs ride the
                    // worker's single writer channel behind reply
                    // frames, so a loaded worker's pong can lag a full
                    // interval legitimately. Leave the ping outstanding
                    // and only declare death after `health_misses`
                    // consecutive silent intervals.
                    let sent = conn.last_ping.load(Ordering::Acquire);
                    let got = conn.last_pong.load(Ordering::Acquire);
                    if sent != 0 && got < sent {
                        let missed = conn.missed.fetch_add(1, Ordering::AcqRel) + 1;
                        if missed >= shared.cfg.health_misses {
                            shared.mark_down(idx, &conn);
                        }
                        continue;
                    }
                    conn.missed.store(0, Ordering::Release);
                    let nonce = shared.next_nonce.fetch_add(1, Ordering::Relaxed);
                    conn.last_ping.store(nonce, Ordering::Release);
                    let wrote = {
                        let mut w = lock_recover(&conn.writer);
                        write_frame(&mut *w, &Frame::HealthPing { nonce })
                            .and_then(|()| w.flush().map_err(MpError::from))
                    };
                    if wrote.is_err() {
                        shared.mark_down(idx, &conn);
                    }
                }
                None => {
                    // Dead: probe with a throwaway connection. Only a
                    // full connect + handshake + ping/pong counts as a
                    // pass.
                    let passed = probe(
                        &shared.workers[idx].addr,
                        shared.cfg.connect_timeout,
                        shared.cfg.health_interval.max(Duration::from_millis(50)),
                    );
                    let mut state = lock_recover(&shared.workers[idx].state);
                    if let SlotState::Down { passes } = &mut *state {
                        if passed {
                            *passes += 1;
                            if *passes >= shared.cfg.health_passes {
                                drop(state);
                                if establish(shared, idx).is_ok() {
                                    shared.metrics.workers_readmitted.inc();
                                } else {
                                    *lock_recover(&shared.workers[idx].state) =
                                        SlotState::Down { passes: 0 };
                                }
                            }
                        } else {
                            *passes = 0;
                        }
                    }
                }
            }
        }
    }
}

/// One synchronous liveness probe: connect, handshake, ping, pong.
fn probe(addr: &str, connect_timeout: Duration, read_timeout: Duration) -> bool {
    let mut stream = match connect(addr, connect_timeout) {
        Ok(s) => s,
        Err(_) => return false,
    };
    if stream.set_read_timeout(Some(read_timeout)).is_err() {
        return false;
    }
    if handshake(&mut stream).is_err() {
        return false;
    }
    if write_frame(&mut stream, &Frame::HealthPing { nonce: u64::MAX }).is_err() {
        return false;
    }
    let _ = stream.flush();
    loop {
        match read_frame(&mut stream) {
            Ok(Frame::HealthPong { nonce, .. }) if nonce == u64::MAX => return true,
            Ok(_) => continue,
            Err(_) => return false,
        }
    }
}
