//! The live **graph registry**: named, versioned, pre-validated graph
//! configs that serving resolves at checkout time — the paper's §2
//! promise ("iterate on the pipeline by editing the config, not the
//! code") made operational.
//!
//! A [`GraphRegistry`] maps names to the *current* [`GraphVersion`] of
//! a config. Registering or swapping a config validates it **once**
//! (subgraph expansion + planning); the resulting [`Plan`] travels with
//! the version, so a bad config is rejected at [`GraphRegistry::swap`]
//! time — never at checkout, never on the request path — and every
//! later [`GraphVersion::build_graph`] skips straight to calculator
//! instantiation.
//!
//! [`GraphRegistry::swap`] publishes a new version atomically: a
//! [`crate::serving::GraphPool`] bound to the registry resolves the
//! current version per checkout, so new checkouts (and the refill
//! worker's prewarm pass) build against the new config while anything
//! already checked out keeps running — and draining — on the `Arc` of
//! the old version it pinned. That is the blue-green half the pool and
//! server build on (see "Graph registry & hot-swap" in
//! [`crate::serving`]'s module docs).
//!
//! The **scenario catalog** ([`install_catalog`]) ships three real
//! multi-model pipelines on top of the registry: a pose-landmark graph
//! (33-point skeleton + joint angles), a holistic pose/hands/face graph
//! running three landmarkers as parallel subgraphs with synchronized
//! output, and a detection→tracking→landmark cascade. The factory +
//! metadata shape follows `rust/src/registry.rs` (the calculator
//! registry): one `RwLock<HashMap>` keyed by name, values carrying
//! everything needed to instantiate.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, OnceLock, RwLock};

use crate::error::{MpError, MpResult};
use crate::executor::Executor;
use crate::graph::{expand_subgraphs, plan, Graph, GraphConfig, Plan, SubgraphRegistry};
use crate::registry::CalculatorRegistry;
use crate::serving::payload::IoDescriptor;

/// One validated, immutable version of a named graph config. Holders
/// (pooled graphs, streaming sessions) pin the version they were built
/// from via `Arc`; version identity is `Arc` pointer identity, so a
/// re-registration of a byte-identical config is still a *new* version.
pub struct GraphVersion {
    name: String,
    version: u64,
    /// The **expanded** config (subgraphs inlined) the plan was derived
    /// from; also the source of truth for declared side packets.
    config: GraphConfig,
    plan: Plan,
    /// The serving I/O contract inferred from the plan's declared port
    /// types — input/output stream names and payload kinds, computed
    /// once here at validation time, never on the request path.
    descriptor: IoDescriptor,
}

impl GraphVersion {
    /// Validate `config` (expansion + planning against the global
    /// registries) into a version. All registration paths funnel here:
    /// a config that passes is buildable, one that does not never
    /// enters a registry.
    fn validate(name: &str, version: u64, config: &GraphConfig) -> MpResult<GraphVersion> {
        crate::serving::pipeline::ensure_registered();
        let expanded = expand_subgraphs(
            config,
            SubgraphRegistry::global(),
            CalculatorRegistry::global(),
        )?;
        let plan = plan(&expanded, CalculatorRegistry::global())?;
        let descriptor = IoDescriptor::infer(&expanded, &plan);
        Ok(GraphVersion {
            name: name.to_string(),
            version,
            config: expanded,
            plan,
            descriptor,
        })
    }

    /// Validate a config outside any registry (version 1). This is how
    /// a fixed-config [`crate::serving::GraphPool`] wraps its config, so
    /// the registry and legacy pool paths share one validation seam.
    pub fn standalone(name: &str, config: &GraphConfig) -> MpResult<Arc<GraphVersion>> {
        Ok(Arc::new(GraphVersion::validate(name, 1, config)?))
    }

    pub fn name(&self) -> &str {
        &self.name
    }

    /// Monotone per-name version number (1 on first registration).
    pub fn version(&self) -> u64 {
        self.version
    }

    /// The expanded config this version validated as.
    pub fn config(&self) -> &GraphConfig {
        &self.config
    }

    pub fn plan(&self) -> &Plan {
        &self.plan
    }

    /// The serving I/O contract this version validated with: declared
    /// input/output streams and payload kinds ([`IoDescriptor`]).
    pub fn descriptor(&self) -> &IoDescriptor {
        &self.descriptor
    }

    /// Instantiate a fresh graph of this version — no re-validation,
    /// just calculator construction ([`Graph::from_validated`]).
    pub fn build_graph(&self, executor: Option<Arc<dyn Executor>>) -> MpResult<Graph> {
        Graph::from_validated(self.plan.clone(), &self.config, executor)
    }
}

impl std::fmt::Debug for GraphVersion {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("GraphVersion")
            .field("name", &self.name)
            .field("version", &self.version)
            .field("nodes", &self.plan.nodes.len())
            .finish()
    }
}

/// Name → current [`GraphVersion`]. `register` admits new names,
/// `swap` publishes the next version of an existing (or new) name;
/// both validate before anything becomes visible.
#[derive(Default)]
pub struct GraphRegistry {
    map: RwLock<HashMap<String, Arc<GraphVersion>>>,
    /// Successful `swap` publications (evidence counter).
    swaps: AtomicU64,
}

impl GraphRegistry {
    pub fn new() -> GraphRegistry {
        GraphRegistry::default()
    }

    /// The process-global registry, pre-loaded with the scenario
    /// catalog (mirrors [`CalculatorRegistry::global`], which pre-loads
    /// the built-in calculators). Returned as an `Arc` so pools and
    /// servers can hold it like any caller-provided registry.
    pub fn global() -> Arc<GraphRegistry> {
        static GLOBAL: OnceLock<Arc<GraphRegistry>> = OnceLock::new();
        Arc::clone(GLOBAL.get_or_init(|| {
            let r = GraphRegistry::new();
            // The built-in catalog must validate; a failure here is a
            // programming error, not an input error.
            install_catalog(&r).expect("built-in scenario catalog must validate");
            Arc::new(r)
        }))
    }

    /// Register a **new** name (version 1). Fails if the name is taken
    /// (use [`GraphRegistry::swap`] to publish a successor version) or
    /// if the config does not validate.
    pub fn register(&self, name: &str, config: &GraphConfig) -> MpResult<Arc<GraphVersion>> {
        let version = Arc::new(GraphVersion::validate(name, 1, config)?);
        let mut map = self.map.write().unwrap();
        if map.contains_key(name) {
            return Err(MpError::Validation(format!(
                "graph '{name}' is already registered; use swap to publish a new version"
            )));
        }
        map.insert(name.to_string(), Arc::clone(&version));
        Ok(version)
    }

    /// Validate `config` and publish it as the next version of `name`
    /// (version N+1 for an existing name, 1 for a new one). On
    /// validation failure the current version stays published untouched
    /// — a bad config can never take a name down. A successor must keep
    /// the predecessor's [`IoDescriptor`]: a blue-green swap changes the
    /// graph *behind* the serving contract, never the contract itself
    /// (in-flight clients hold typed expectations about both versions).
    pub fn swap(&self, name: &str, config: &GraphConfig) -> MpResult<Arc<GraphVersion>> {
        // Validate before taking the write lock: planning is the
        // expensive part and needs no registry state.
        let mut candidate = GraphVersion::validate(name, 1, config)?;
        let mut map = self.map.write().unwrap();
        if let Some(cur) = map.get(name) {
            if cur.descriptor != candidate.descriptor {
                return Err(MpError::Validation(format!(
                    "swap of '{name}' changes its serving I/O contract \
                     ({:?} -> {:?}); register a new name instead",
                    cur.descriptor, candidate.descriptor
                )));
            }
            candidate.version = cur.version + 1;
        }
        let version = Arc::new(candidate);
        map.insert(name.to_string(), Arc::clone(&version));
        self.swaps.fetch_add(1, Ordering::AcqRel);
        Ok(version)
    }

    /// The current version of `name`.
    pub fn get(&self, name: &str) -> MpResult<Arc<GraphVersion>> {
        self.map
            .read()
            .unwrap()
            .get(name)
            .cloned()
            .ok_or_else(|| MpError::Validation(format!("no graph named '{name}' is registered")))
    }

    pub fn contains(&self, name: &str) -> bool {
        self.map.read().unwrap().contains_key(name)
    }

    pub fn names(&self) -> Vec<String> {
        let mut names: Vec<String> = self.map.read().unwrap().keys().cloned().collect();
        names.sort();
        names
    }

    /// Successful `swap` publications so far.
    pub fn swaps(&self) -> u64 {
        self.swaps.load(Ordering::Acquire)
    }
}

impl std::fmt::Debug for GraphRegistry {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("GraphRegistry")
            .field("names", &self.names())
            .field("swaps", &self.swaps())
            .finish()
    }
}

// ---------------------------------------------------------------------
// The scenario catalog
// ---------------------------------------------------------------------

/// Catalog name: pose detector → temporal smoother → joint angles
/// (Snippet 1: 33-point skeleton + joint-angle decoding).
pub const POSE_LANDMARK: &str = "pose_landmark";
/// Catalog name: pose + hands + face landmarkers as parallel subgraphs,
/// merged into one synchronized holistic result (Snippet 2).
pub const HOLISTIC: &str = "holistic_multi_model";
/// Catalog name: sparse detection → per-frame box tracking (loopback) →
/// per-detection landmarks (§6.1's cascade shape).
pub const DETECTION_CASCADE: &str = "detection_cascade";

/// Register the landmarker subgraphs the holistic scenario instantiates
/// (idempotent; `register_as` overwrites byte-identical definitions).
fn ensure_scenario_subgraphs() {
    static ONCE: OnceLock<()> = OnceLock::new();
    ONCE.get_or_init(|| {
        let subs = SubgraphRegistry::global();
        subs.register_as(
            "PoseLandmarkerSubgraph",
            GraphConfig::parse(
                r#"
input_stream: "IN:sub_frame"
output_stream: "OUT:sub_pose"
node { calculator: "PoseDetectorCalculator" input_stream: "FRAME:sub_frame" output_stream: "POSE:raw_pose" }
node { calculator: "LandmarkSmootherCalculator" input_stream: "raw_pose" output_stream: "sub_pose" options { alpha: 0.6 } }
"#,
            )
            .expect("pose subgraph parses"),
        );
        subs.register_as(
            "HandLandmarkerSubgraph",
            GraphConfig::parse(
                r#"
input_stream: "IN:sub_frame"
output_stream: "OUT:sub_hands"
node { calculator: "HandLandmarkerCalculator" input_stream: "FRAME:sub_frame" output_stream: "HANDS:sub_hands" }
"#,
            )
            .expect("hand subgraph parses"),
        );
        subs.register_as(
            "FaceLandmarkerSubgraph",
            GraphConfig::parse(
                r#"
input_stream: "IN:sub_frame"
output_stream: "OUT:sub_face"
node { calculator: "FaceLandmarkerCalculator" input_stream: "FRAME:sub_frame" output_stream: "FACE:sub_face" }
"#,
            )
            .expect("face subgraph parses"),
        );
    });
}

/// Snippet 1: frame → 33-point pose → smoother → joint angles. Outputs:
/// `pose` ([`crate::perception::LandmarkList`]) and `angles`
/// ([`crate::calculators::scenarios::JointAngles`]) on every frame.
pub fn pose_landmark_config() -> GraphConfig {
    GraphConfig::parse(
        r#"
input_stream: "frame"
output_stream: "pose"
output_stream: "angles"
node { calculator: "PoseDetectorCalculator" input_stream: "FRAME:frame" output_stream: "POSE:raw_pose" }
node { calculator: "LandmarkSmootherCalculator" input_stream: "raw_pose" output_stream: "pose" options { alpha: 0.6 } }
node { calculator: "JointAngleCalculator" input_stream: "POSE:pose" output_stream: "ANGLES:angles" }
"#,
    )
    .expect("pose_landmark config parses")
}

/// Snippet 2: three landmarker **subgraphs** fan out from one frame
/// stream and run in parallel; the merger's default aligned-timestamp
/// policy re-synchronizes them, so each `holistic` packet carries the
/// pose, hands and face of exactly one frame.
pub fn holistic_config() -> GraphConfig {
    ensure_scenario_subgraphs();
    GraphConfig::parse(
        r#"
input_stream: "frame"
output_stream: "holistic"
node { calculator: "PoseLandmarkerSubgraph" name: "pose_branch" input_stream: "IN:frame" output_stream: "OUT:pose" }
node { calculator: "HandLandmarkerSubgraph" name: "hand_branch" input_stream: "IN:frame" output_stream: "OUT:hands" }
node { calculator: "FaceLandmarkerSubgraph" name: "face_branch" input_stream: "IN:frame" output_stream: "OUT:face" }
node {
  calculator: "HolisticMergerCalculator"
  input_stream: "POSE:pose"
  input_stream: "HANDS:hands"
  input_stream: "FACE:face"
  output_stream: "HOLISTIC:holistic"
}
"#,
    )
    .expect("holistic config parses")
}

/// §6.1's cascade: a sparse detector (every 3rd frame) feeds a
/// per-frame box tracker through the merged-detections loopback; the
/// tracked boxes drive per-detection landmarks on every frame. Outputs:
/// `tracked` ([`crate::perception::Detections`]) and `landmarks`.
pub fn detection_cascade_config() -> GraphConfig {
    GraphConfig::parse(
        r#"
input_stream: "frame"
output_stream: "tracked"
output_stream: "landmarks"
node {
  calculator: "FrameSelectionCalculator"
  input_stream: "FRAME:frame"
  output_stream: "FRAME:selected"
  options { mode: "period" period: 3 }
}
node {
  calculator: "TemplateMatchDetectorCalculator"
  input_stream: "FRAME:selected"
  output_stream: "DETECTIONS:fresh"
  options { grid: 8 min_score: 0.2 box_size: 0.2 }
}
node {
  calculator: "TrackedDetectionMergerCalculator"
  input_stream: "DETECTIONS:fresh"
  input_stream: "TRACKED:tracked"
  output_stream: "MERGED:merged"
  options { iou_threshold: 0.1 }
}
node {
  calculator: "BoxTrackerCalculator"
  input_stream: "FRAME:frame"
  back_edge_input_stream: "DETECTIONS:merged"
  output_stream: "TRACKED:tracked"
}
node {
  calculator: "DetectionLandmarksCalculator"
  input_stream: "FRAME:frame"
  input_stream: "DETECTIONS:tracked"
  output_stream: "LANDMARKS:landmarks"
}
"#,
    )
    .expect("detection_cascade config parses")
}

/// Install the three catalog scenarios into `registry` (validating each
/// — installation doubles as a proof the catalog plans). Idempotent:
/// already-present names are left at their current version.
pub fn install_catalog(registry: &GraphRegistry) -> MpResult<()> {
    ensure_scenario_subgraphs();
    for (name, config) in [
        (POSE_LANDMARK, pose_landmark_config()),
        (HOLISTIC, holistic_config()),
        (DETECTION_CASCADE, detection_cascade_config()),
    ] {
        if !registry.contains(name) {
            registry.register(name, &config)?;
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn chain(n: usize) -> GraphConfig {
        let mut text = String::from("input_stream: \"in\"\noutput_stream: \"out\"\n");
        for i in 0..n {
            let src = if i == 0 { "in".into() } else { format!("s{i}") };
            let dst = if i + 1 == n {
                "out".into()
            } else {
                format!("s{}", i + 1)
            };
            text.push_str(&format!(
                "node {{ calculator: \"PassThroughCalculator\" input_stream: \"{src}\" output_stream: \"{dst}\" }}\n"
            ));
        }
        GraphConfig::parse(&text).unwrap()
    }

    #[test]
    fn register_get_and_swap_version_lifecycle() {
        let reg = GraphRegistry::new();
        let v1 = reg.register("p", &chain(2)).unwrap();
        assert_eq!((v1.name(), v1.version()), ("p", 1));
        assert_eq!(v1.plan().nodes.len(), 2);
        // Duplicate registration is rejected; swap publishes v2.
        assert!(reg.register("p", &chain(2)).is_err());
        let v2 = reg.swap("p", &chain(3)).unwrap();
        assert_eq!(v2.version(), 2);
        assert_eq!(reg.swaps(), 1);
        let cur = reg.get("p").unwrap();
        assert!(Arc::ptr_eq(&cur, &v2));
        assert!(!Arc::ptr_eq(&cur, &v1));
        // The old Arc stays fully usable (in-flight holders drain on it).
        assert_eq!(v1.plan().nodes.len(), 2);
        // Swap on a new name starts at version 1.
        let q1 = reg.swap("q", &chain(1)).unwrap();
        assert_eq!(q1.version(), 1);
        assert_eq!(reg.names(), vec!["p".to_string(), "q".to_string()]);
    }

    #[test]
    fn bad_config_is_rejected_at_registration_not_checkout() {
        let reg = GraphRegistry::new();
        let good = chain(2);
        reg.register("p", &good).unwrap();
        let bad =
            GraphConfig::parse(r#"node { calculator: "NoSuchCalculator" input_stream: "x" }"#)
                .unwrap();
        assert!(reg.swap("p", &bad).is_err(), "invalid config must not publish");
        // The previous version survived the failed swap.
        let cur = reg.get("p").unwrap();
        assert_eq!(cur.version(), 1);
        assert!(cur.build_graph(None).is_ok());
        assert_eq!(reg.swaps(), 0);
    }

    #[test]
    fn version_builds_graphs_without_revalidation() {
        let reg = GraphRegistry::new();
        let v = reg.register("p", &chain(2)).unwrap();
        let g = v.build_graph(None).unwrap();
        assert_eq!(g.plan().nodes.len(), 2);
    }

    #[test]
    fn missing_name_is_a_clean_error() {
        let reg = GraphRegistry::new();
        let err = reg.get("ghost").unwrap_err();
        assert!(err.to_string().contains("ghost"));
    }

    #[test]
    fn catalog_installs_and_all_scenarios_validate() {
        let reg = GraphRegistry::new();
        install_catalog(&reg).unwrap();
        install_catalog(&reg).unwrap(); // idempotent
        for name in [POSE_LANDMARK, HOLISTIC, DETECTION_CASCADE] {
            let v = reg.get(name).unwrap();
            assert_eq!(v.version(), 1, "{name} not re-registered");
            assert!(v.plan().nodes.len() >= 3, "{name} expanded to real nodes");
        }
        // The holistic graph's subgraphs inlined into parallel branches.
        let h = reg.get(HOLISTIC).unwrap();
        assert!(
            h.plan().nodes.len() >= 5,
            "three branches + merger after expansion: {}",
            h.plan().nodes.len()
        );
    }

    #[test]
    fn catalog_descriptors_declare_typed_io() {
        use crate::serving::payload::PayloadKind;
        let reg = GraphRegistry::new();
        install_catalog(&reg).unwrap();
        let pose = reg.get(POSE_LANDMARK).unwrap();
        let d = pose.descriptor();
        assert_eq!(d.input_stream, "frame");
        assert_eq!(d.input_kind, PayloadKind::Frame);
        assert!(!d.batched);
        assert_eq!(
            d.outputs,
            vec![
                ("pose".to_string(), PayloadKind::Landmarks),
                ("angles".to_string(), PayloadKind::Map),
            ]
        );
        d.ensure_servable().unwrap();
        let holistic = reg.get(HOLISTIC).unwrap();
        assert_eq!(
            holistic.descriptor().outputs,
            vec![("holistic".to_string(), PayloadKind::Map)]
        );
        holistic.descriptor().ensure_servable().unwrap();
        let cascade = reg.get(DETECTION_CASCADE).unwrap();
        assert_eq!(
            cascade.descriptor().outputs,
            vec![
                ("tracked".to_string(), PayloadKind::Detections),
                ("landmarks".to_string(), PayloadKind::Landmarks),
            ]
        );
        cascade.descriptor().ensure_servable().unwrap();
    }

    #[test]
    fn swap_rejects_an_io_contract_change() {
        let reg = GraphRegistry::new();
        install_catalog(&reg).unwrap();
        // pose_landmark (frame → landmarks+angles) cannot be replaced by
        // a passthrough chain (opaque in/out) under the same name.
        let err = reg.swap(POSE_LANDMARK, &chain(2)).unwrap_err();
        assert!(matches!(err, MpError::Validation(_)));
        assert!(err.to_string().contains("I/O contract"));
        // The incumbent version survived the refused swap.
        assert_eq!(reg.get(POSE_LANDMARK).unwrap().version(), 1);
        assert_eq!(reg.swaps(), 0);
        // A same-shape successor still publishes.
        let v2 = reg.swap(POSE_LANDMARK, &pose_landmark_config()).unwrap();
        assert_eq!(v2.version(), 2);
    }
}
