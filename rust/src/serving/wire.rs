//! The distributed-serving **wire format**: a dependency-free,
//! length-prefixed binary framing for requests, replies, typed errors,
//! and health/metrics frames (serving module docs, "Distributed
//! serving").
//!
//! Every frame on the socket is `u32 length (LE)` followed by `length`
//! body bytes; the body is a one-byte tag plus tag-specific fields, all
//! little-endian, strings and payload bodies length-prefixed. There is
//! deliberately no self-describing schema layer — the format is
//! versioned as a whole through the [`Frame::Hello`] handshake
//! ([`WIRE_VERSION`]), matching the crate's zero-dependency rule.
//!
//! Four properties the rest of the distributed layer leans on:
//!
//! * **Typed payloads round-trip.** Requests and Ok replies carry one
//!   tagged [`ServingPayload`] — image frame, f32 tensor, detection
//!   list, landmark list, or a named map of payloads (recursive, depth
//!   bounded by [`MAX_PAYLOAD_DEPTH`] on decode) — so every catalog
//!   graph serves over the wire with the same types it serves
//!   in-process. A frame payload's declared dimensions are validated
//!   against its pixel count at decode time; a mismatch is a typed
//!   decode error, never a panic downstream.
//! * **Typed errors round-trip.** [`MpError::Overloaded`],
//!   [`MpError::DeadlineExceeded`], [`MpError::TimestampViolation`] and
//!   [`MpError::WorkerLost`] cross the hop field-for-field, so a router
//!   client can match on the variant exactly as a local caller would;
//!   every other variant degrades to its display string (decoded as
//!   [`MpError::Runtime`]).
//! * **Explicit timestamps.** A [`WireRequest`] carries the session's
//!   timestamp and the reply echoes it, so streaming-session watermark
//!   semantics survive the hop: the worker enforces per-session
//!   monotonicity on the wire timestamp and answers a stale or
//!   duplicate one with the same typed `TimestampViolation` a local
//!   [`crate::serving::StreamingSession`] submission would raise.
//! * **Relative deadlines.** A request's deadline crosses the wire as a
//!   *remaining budget* in µs, not an absolute instant — wall clocks
//!   do not cross process boundaries. The worker re-anchors the budget
//!   at arrival, which is conservative by exactly the transit time.
//!
//! Bounded intake at the codec layer: a declared frame length beyond
//! [`MAX_FRAME_LEN`] is rejected before any allocation, so a garbage
//! (or hostile) peer cannot make a worker allocate unbounded memory
//! from four bytes of input.

use std::io::{Read, Write};

use crate::error::{MpError, MpResult};
use crate::perception::types::{Detection, Detections, LandmarkList, Rect};
use crate::perception::ImageFrame;
use crate::serving::payload::ServingPayload;

/// Version negotiated by the [`Frame::Hello`] handshake. Bump on any
/// encoding change; peers refuse mismatched versions. Version 2
/// replaced the raw request pixel body with tagged [`ServingPayload`]
/// encodings on both requests and replies.
pub const WIRE_VERSION: u16 = 2;

/// Hard cap on one frame's body length (64 MiB): frames declaring more
/// are rejected before allocation. Enforced on **both** sides —
/// [`read_frame`] refuses a declared length beyond it, and
/// [`write_frame`] refuses to send a body beyond it (the peer would
/// reject the length and sever the connection, taking every in-flight
/// request on it down).
pub const MAX_FRAME_LEN: usize = 1 << 26;

/// Fixed bytes of a [`Frame::Request`] body before its payload: tag,
/// id, session, timestamp, deadline. Kept in sync with `encode_frame`.
/// Senders pre-check `REQUEST_OVERHEAD + payload_encoded_len(&p)`
/// against [`MAX_FRAME_LEN`] (the router does, in `submit_inner`) so
/// they never produce a request the peer's codec is guaranteed to
/// reject.
pub const REQUEST_OVERHEAD: usize = 1 + 8 + 8 + 8 + 8;

/// Fixed bytes of a request body carrying a frame payload: the request
/// overhead plus the frame payload's header (payload tag,
/// width/height/channels, pixel count). Kept in sync with
/// `put_payload`.
const REQUEST_BODY_OVERHEAD: usize = REQUEST_OVERHEAD + 1 + 4 + 4 + 4 + 4;

/// Most pixels one frame-payload request can carry without its body
/// exceeding [`MAX_FRAME_LEN`].
pub const MAX_REQUEST_PIXELS: usize = (MAX_FRAME_LEN - REQUEST_BODY_OVERHEAD) / 4;

/// Decode-side bound on [`ServingPayload::Map`] nesting: a body can
/// declare maps-in-maps, and an unbounded recursive decode would turn
/// 64 MiB of nested tags into a stack overflow. The catalog needs
/// depth 2 (a map of landmark lists); 8 leaves headroom.
pub const MAX_PAYLOAD_DEPTH: usize = 8;

/// Sentinel for "no deadline" in [`WireRequest::deadline_us`].
pub const NO_DEADLINE: u64 = u64::MAX;

/// One inference request crossing the wire (router → worker).
#[derive(Clone, Debug, PartialEq)]
pub struct WireRequest {
    /// Correlation id: the reply echoes it; unique per connection.
    pub id: u64,
    /// The streaming session this request belongs to. The worker keeps
    /// one reply-FIFO client and one timestamp watermark per session.
    pub session: u64,
    /// The session's explicit timestamp for this request (strictly
    /// monotone per session — the watermark the worker enforces).
    pub timestamp: i64,
    /// Remaining deadline budget in µs ([`NO_DEADLINE`] = none),
    /// re-anchored at the worker on arrival.
    pub deadline_us: u64,
    /// The request's typed payload, already validated by the decoder
    /// (frame dimensions match the pixel count, map nesting bounded).
    /// The worker **moves** it into submission — decode allocates each
    /// payload exactly once; nothing on the request path clones it.
    pub payload: ServingPayload,
}

impl WireRequest {
    /// Move the payload out for submission, leaving a cheap empty
    /// tensor behind (the request header stays readable for reply
    /// correlation).
    pub fn take_payload(&mut self) -> ServingPayload {
        std::mem::replace(&mut self.payload, ServingPayload::Tensor(Vec::new()))
    }
}

/// One reply crossing the wire (worker → router), demuxed by `id`.
#[derive(Clone, Debug)]
pub struct WireReply {
    pub id: u64,
    pub session: u64,
    /// Echo of the request's timestamp (watermark evidence).
    pub timestamp: i64,
    pub result: MpResult<ServingPayload>,
}

/// Worker-side load evidence carried on every health pong.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct WorkerStats {
    /// Requests answered Ok over the worker's life.
    pub requests: u64,
    /// Requests answered with an error.
    pub errors: u64,
    /// Requests shed at admission ([`MpError::Overloaded`]).
    pub shed: u64,
    /// Requests expired in queue ([`MpError::DeadlineExceeded`]).
    pub expired: u64,
    /// Live wire sessions across the worker's connections.
    pub sessions: u64,
}

/// Everything that can cross the socket.
#[derive(Clone, Debug)]
pub enum Frame {
    /// Connection handshake: each side sends its version first; a peer
    /// speaking another version is refused.
    Hello { version: u16 },
    Request(WireRequest),
    Reply(WireReply),
    /// Router → worker liveness probe.
    HealthPing { nonce: u64 },
    /// Worker → router: echo the nonce plus load evidence.
    HealthPong { nonce: u64, stats: WorkerStats },
    /// Router → worker: ask for the full metrics report.
    MetricsRequest,
    /// Worker → router: the server's metrics report, verbatim.
    MetricsReport { text: String },
    /// Planned shutdown: the sender stops accepting new work; the
    /// receiver retires and reroutes the affected sessions.
    Goodbye { reason: String },
}

const TAG_HELLO: u8 = 1;
const TAG_REQUEST: u8 = 2;
const TAG_REPLY: u8 = 3;
const TAG_PING: u8 = 4;
const TAG_PONG: u8 = 5;
const TAG_METRICS_REQUEST: u8 = 6;
const TAG_METRICS_REPORT: u8 = 7;
const TAG_GOODBYE: u8 = 8;

/// Typed-error tags inside a [`WireReply`] (module docs: these four
/// round-trip field-for-field; everything else is a display string).
const ERR_OVERLOADED: u8 = 0;
const ERR_DEADLINE: u8 = 1;
const ERR_TS_VIOLATION: u8 = 2;
const ERR_WORKER_LOST: u8 = 3;
const ERR_OTHER: u8 = 4;

/// [`ServingPayload`] variant tags (requests and Ok replies).
const P_FRAME: u8 = 0;
const P_TENSOR: u8 = 1;
const P_DETECTIONS: u8 = 2;
const P_LANDMARKS: u8 = 3;
const P_MAP: u8 = 4;

fn wire_err(msg: impl Into<String>) -> MpError {
    MpError::Io(format!("wire: {}", msg.into()))
}

// ---------------------------------------------------------------------
// Encoding
// ---------------------------------------------------------------------

fn put_u8(b: &mut Vec<u8>, v: u8) {
    b.push(v);
}

fn put_u16(b: &mut Vec<u8>, v: u16) {
    b.extend_from_slice(&v.to_le_bytes());
}

fn put_u32(b: &mut Vec<u8>, v: u32) {
    b.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(b: &mut Vec<u8>, v: u64) {
    b.extend_from_slice(&v.to_le_bytes());
}

fn put_i64(b: &mut Vec<u8>, v: i64) {
    b.extend_from_slice(&v.to_le_bytes());
}

fn put_f32(b: &mut Vec<u8>, v: f32) {
    b.extend_from_slice(&v.to_le_bytes());
}

fn put_str(b: &mut Vec<u8>, s: &str) {
    put_u32(b, s.len() as u32);
    b.extend_from_slice(s.as_bytes());
}

fn put_error(b: &mut Vec<u8>, e: &MpError) {
    match e {
        MpError::Overloaded {
            queued,
            estimated_wait_us,
        } => {
            put_u8(b, ERR_OVERLOADED);
            put_u64(b, *queued as u64);
            put_u64(b, *estimated_wait_us);
        }
        MpError::DeadlineExceeded { waited_us } => {
            put_u8(b, ERR_DEADLINE);
            put_u64(b, *waited_us);
        }
        MpError::TimestampViolation {
            stream,
            packet_ts,
            bound,
        } => {
            put_u8(b, ERR_TS_VIOLATION);
            put_str(b, stream);
            put_i64(b, *packet_ts);
            put_i64(b, *bound);
        }
        MpError::WorkerLost { worker } => {
            put_u8(b, ERR_WORKER_LOST);
            put_str(b, worker);
        }
        other => {
            put_u8(b, ERR_OTHER);
            put_str(b, &other.to_string());
        }
    }
}

fn put_detections(b: &mut Vec<u8>, dets: &Detections) {
    put_u32(b, dets.len() as u32);
    for d in dets {
        put_f32(b, d.bbox.x);
        put_f32(b, d.bbox.y);
        put_f32(b, d.bbox.w);
        put_f32(b, d.bbox.h);
        put_f32(b, d.score);
        put_u32(b, d.class_id);
        match d.track_id {
            Some(t) => {
                put_u8(b, 1);
                put_u64(b, t);
            }
            None => put_u8(b, 0),
        }
    }
}

/// Encode one tagged [`ServingPayload`] (requests and Ok replies).
/// Map entries recurse; the *decoder* bounds nesting at
/// [`MAX_PAYLOAD_DEPTH`], so a deeper map encodes fine locally but is
/// refused by every conforming peer.
fn put_payload(b: &mut Vec<u8>, p: &ServingPayload) {
    match p {
        ServingPayload::Frame(f) => {
            put_u8(b, P_FRAME);
            put_u32(b, f.width as u32);
            put_u32(b, f.height as u32);
            put_u32(b, f.channels as u32);
            put_u32(b, f.data.len() as u32);
            for v in f.data.iter() {
                put_f32(b, *v);
            }
        }
        ServingPayload::Tensor(t) => {
            put_u8(b, P_TENSOR);
            put_u32(b, t.len() as u32);
            for v in t {
                put_f32(b, *v);
            }
        }
        ServingPayload::Detections(d) => {
            put_u8(b, P_DETECTIONS);
            put_detections(b, d);
        }
        ServingPayload::Landmarks(l) => {
            put_u8(b, P_LANDMARKS);
            put_u32(b, l.points.len() as u32);
            for (x, y) in &l.points {
                put_f32(b, *x);
                put_f32(b, *y);
            }
        }
        ServingPayload::Map(m) => {
            put_u8(b, P_MAP);
            put_u32(b, m.len() as u32);
            for (name, entry) in m {
                put_str(b, name);
                put_payload(b, entry);
            }
        }
    }
}

/// Exact encoded length of one payload — the send-side pre-check
/// ([`REQUEST_OVERHEAD`] + this against [`MAX_FRAME_LEN`]) without
/// encoding anything.
pub fn payload_encoded_len(p: &ServingPayload) -> usize {
    match p {
        ServingPayload::Frame(f) => 1 + 4 * 4 + 4 * f.data.len(),
        ServingPayload::Tensor(t) => 1 + 4 + 4 * t.len(),
        ServingPayload::Detections(d) => {
            // Per detection: bbox + score (5 × f32), class id, and the
            // track-id presence byte (+8 when present).
            1 + 4
                + d.iter()
                    .map(|det| 5 * 4 + 4 + 1 + if det.track_id.is_some() { 8 } else { 0 })
                    .sum::<usize>()
        }
        ServingPayload::Landmarks(l) => 1 + 4 + 8 * l.points.len(),
        ServingPayload::Map(m) => {
            1 + 4
                + m.iter()
                    .map(|(name, entry)| 4 + name.len() + payload_encoded_len(entry))
                    .sum::<usize>()
        }
    }
}

/// Encode `frame` as one length-prefixed wire frame.
pub fn encode_frame(frame: &Frame) -> Vec<u8> {
    let mut body = Vec::new();
    match frame {
        Frame::Hello { version } => {
            put_u8(&mut body, TAG_HELLO);
            put_u16(&mut body, *version);
        }
        Frame::Request(r) => {
            put_u8(&mut body, TAG_REQUEST);
            put_u64(&mut body, r.id);
            put_u64(&mut body, r.session);
            put_i64(&mut body, r.timestamp);
            put_u64(&mut body, r.deadline_us);
            put_payload(&mut body, &r.payload);
        }
        Frame::Reply(r) => {
            put_u8(&mut body, TAG_REPLY);
            put_u64(&mut body, r.id);
            put_u64(&mut body, r.session);
            put_i64(&mut body, r.timestamp);
            match &r.result {
                Ok(payload) => {
                    put_u8(&mut body, 1);
                    put_payload(&mut body, payload);
                }
                Err(e) => {
                    put_u8(&mut body, 0);
                    put_error(&mut body, e);
                }
            }
        }
        Frame::HealthPing { nonce } => {
            put_u8(&mut body, TAG_PING);
            put_u64(&mut body, *nonce);
        }
        Frame::HealthPong { nonce, stats } => {
            put_u8(&mut body, TAG_PONG);
            put_u64(&mut body, *nonce);
            put_u64(&mut body, stats.requests);
            put_u64(&mut body, stats.errors);
            put_u64(&mut body, stats.shed);
            put_u64(&mut body, stats.expired);
            put_u64(&mut body, stats.sessions);
        }
        Frame::MetricsRequest => {
            put_u8(&mut body, TAG_METRICS_REQUEST);
        }
        Frame::MetricsReport { text } => {
            put_u8(&mut body, TAG_METRICS_REPORT);
            put_str(&mut body, text);
        }
        Frame::Goodbye { reason } => {
            put_u8(&mut body, TAG_GOODBYE);
            put_str(&mut body, reason);
        }
    }
    let mut out = Vec::with_capacity(4 + body.len());
    put_u32(&mut out, body.len() as u32);
    out.extend_from_slice(&body);
    out
}

/// Write one frame (single `write_all`, so a mutex-serialized writer
/// never interleaves frames). Refuses a body beyond [`MAX_FRAME_LEN`]
/// *before* any bytes hit the socket: the peer's [`read_frame`] would
/// reject the declared length and sever the connection, which costs
/// every in-flight request on it — an error here keeps the connection
/// usable.
pub fn write_frame(w: &mut impl Write, frame: &Frame) -> MpResult<()> {
    let bytes = encode_frame(frame);
    let body_len = bytes.len() - 4;
    if body_len > MAX_FRAME_LEN {
        return Err(wire_err(format!(
            "refusing to send a {body_len} byte frame body (cap {MAX_FRAME_LEN}): \
             the peer would reject it and sever the connection"
        )));
    }
    w.write_all(&bytes)?;
    Ok(())
}

// ---------------------------------------------------------------------
// Decoding
// ---------------------------------------------------------------------

/// Bounds-checked cursor over one frame body.
struct Cur<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Cur<'a> {
    fn take(&mut self, n: usize) -> MpResult<&'a [u8]> {
        if self.buf.len() - self.pos < n {
            return Err(wire_err(format!(
                "truncated frame: wanted {n} bytes at offset {}, body is {}",
                self.pos,
                self.buf.len()
            )));
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u8(&mut self) -> MpResult<u8> {
        Ok(self.take(1)?[0])
    }

    fn u16(&mut self) -> MpResult<u16> {
        let s = self.take(2)?;
        Ok(u16::from_le_bytes([s[0], s[1]]))
    }

    fn u32(&mut self) -> MpResult<u32> {
        let s = self.take(4)?;
        Ok(u32::from_le_bytes([s[0], s[1], s[2], s[3]]))
    }

    fn u64(&mut self) -> MpResult<u64> {
        let s = self.take(8)?;
        let mut b = [0u8; 8];
        b.copy_from_slice(s);
        Ok(u64::from_le_bytes(b))
    }

    fn i64(&mut self) -> MpResult<i64> {
        Ok(self.u64()? as i64)
    }

    fn f32(&mut self) -> MpResult<f32> {
        let s = self.take(4)?;
        Ok(f32::from_le_bytes([s[0], s[1], s[2], s[3]]))
    }

    fn str(&mut self) -> MpResult<String> {
        let n = self.u32()? as usize;
        let s = self.take(n)?;
        String::from_utf8(s.to_vec()).map_err(|_| wire_err("string field is not UTF-8"))
    }
}

fn get_error(c: &mut Cur<'_>) -> MpResult<MpError> {
    Ok(match c.u8()? {
        ERR_OVERLOADED => MpError::Overloaded {
            queued: c.u64()? as usize,
            estimated_wait_us: c.u64()?,
        },
        ERR_DEADLINE => MpError::DeadlineExceeded {
            waited_us: c.u64()?,
        },
        ERR_TS_VIOLATION => MpError::TimestampViolation {
            stream: c.str()?,
            packet_ts: c.i64()?,
            bound: c.i64()?,
        },
        ERR_WORKER_LOST => MpError::WorkerLost { worker: c.str()? },
        ERR_OTHER => MpError::Runtime(c.str()?),
        t => return Err(wire_err(format!("unknown error tag {t}"))),
    })
}

fn get_detections(c: &mut Cur<'_>) -> MpResult<Detections> {
    let n = c.u32()? as usize;
    let mut dets = Vec::with_capacity(n.min(1 << 16));
    for _ in 0..n {
        let x = c.f32()?;
        let y = c.f32()?;
        let w = c.f32()?;
        let h = c.f32()?;
        let score = c.f32()?;
        let class_id = c.u32()?;
        let track_id = if c.u8()? != 0 { Some(c.u64()?) } else { None };
        dets.push(Detection {
            bbox: Rect::new(x, y, w, h),
            score,
            class_id,
            track_id,
        });
    }
    Ok(dets)
}

/// Decode one tagged payload. Every size field is validated against the
/// remaining body (allocations are capped at [`MAX_FRAME_LEN`] worth of
/// elements) and frame dimensions are cross-checked against the pixel
/// count *before* an [`ImageFrame`] is built — `ImageFrame::new` asserts
/// on a mismatch, and a corrupt frame must decode to an error, never a
/// panic. Map nesting is bounded by [`MAX_PAYLOAD_DEPTH`] so a crafted
/// body cannot recurse the decoder off the stack.
fn get_payload(c: &mut Cur<'_>, depth: usize) -> MpResult<ServingPayload> {
    Ok(match c.u8()? {
        P_FRAME => {
            let width = c.u32()? as usize;
            let height = c.u32()? as usize;
            let channels = c.u32()? as usize;
            let n = c.u32()? as usize;
            let expected = width
                .checked_mul(height)
                .and_then(|p| p.checked_mul(channels));
            if expected != Some(n) || n == 0 {
                return Err(wire_err(format!(
                    "frame payload dims {width}x{height}x{channels} disagree \
                     with pixel count {n}"
                )));
            }
            let mut pixels = Vec::with_capacity(n.min(MAX_FRAME_LEN / 4));
            for _ in 0..n {
                pixels.push(c.f32()?);
            }
            ServingPayload::Frame(ImageFrame::new(width, height, channels, pixels))
        }
        P_TENSOR => {
            let n = c.u32()? as usize;
            let mut values = Vec::with_capacity(n.min(MAX_FRAME_LEN / 4));
            for _ in 0..n {
                values.push(c.f32()?);
            }
            ServingPayload::Tensor(values)
        }
        P_DETECTIONS => ServingPayload::Detections(get_detections(c)?),
        P_LANDMARKS => {
            let n = c.u32()? as usize;
            let mut points = Vec::with_capacity(n.min(MAX_FRAME_LEN / 8));
            for _ in 0..n {
                let x = c.f32()?;
                let y = c.f32()?;
                points.push((x, y));
            }
            ServingPayload::Landmarks(LandmarkList { points })
        }
        P_MAP => {
            if depth >= MAX_PAYLOAD_DEPTH {
                return Err(wire_err(format!(
                    "map payload nests deeper than {MAX_PAYLOAD_DEPTH} levels"
                )));
            }
            let n = c.u32()? as usize;
            let mut entries = Vec::with_capacity(n.min(1 << 10));
            for _ in 0..n {
                let name = c.str()?;
                let value = get_payload(c, depth + 1)?;
                entries.push((name, value));
            }
            ServingPayload::Map(entries)
        }
        t => return Err(wire_err(format!("unknown payload tag {t}"))),
    })
}

/// Decode one frame body (the bytes after the length prefix).
pub fn decode_body(body: &[u8]) -> MpResult<Frame> {
    let mut c = Cur { buf: body, pos: 0 };
    let frame = match c.u8()? {
        TAG_HELLO => Frame::Hello { version: c.u16()? },
        TAG_REQUEST => {
            let id = c.u64()?;
            let session = c.u64()?;
            let timestamp = c.i64()?;
            let deadline_us = c.u64()?;
            let payload = get_payload(&mut c, 0)?;
            Frame::Request(WireRequest {
                id,
                session,
                timestamp,
                deadline_us,
                payload,
            })
        }
        TAG_REPLY => {
            let id = c.u64()?;
            let session = c.u64()?;
            let timestamp = c.i64()?;
            let result = if c.u8()? != 0 {
                Ok(get_payload(&mut c, 0)?)
            } else {
                Err(get_error(&mut c)?)
            };
            Frame::Reply(WireReply {
                id,
                session,
                timestamp,
                result,
            })
        }
        TAG_PING => Frame::HealthPing { nonce: c.u64()? },
        TAG_PONG => Frame::HealthPong {
            nonce: c.u64()?,
            stats: WorkerStats {
                requests: c.u64()?,
                errors: c.u64()?,
                shed: c.u64()?,
                expired: c.u64()?,
                sessions: c.u64()?,
            },
        },
        TAG_METRICS_REQUEST => Frame::MetricsRequest,
        TAG_METRICS_REPORT => Frame::MetricsReport { text: c.str()? },
        TAG_GOODBYE => Frame::Goodbye { reason: c.str()? },
        t => return Err(wire_err(format!("unknown frame tag {t}"))),
    };
    if c.pos != body.len() {
        return Err(wire_err(format!(
            "frame has {} trailing bytes",
            body.len() - c.pos
        )));
    }
    Ok(frame)
}

/// Read one length-prefixed frame. An `Err` means the connection is
/// unusable (clean EOF included — the peer hung up).
pub fn read_frame(r: &mut impl Read) -> MpResult<Frame> {
    let mut len = [0u8; 4];
    r.read_exact(&mut len)?;
    let len = u32::from_le_bytes(len) as usize;
    if len > MAX_FRAME_LEN {
        return Err(wire_err(format!(
            "declared frame length {len} exceeds the {MAX_FRAME_LEN} cap"
        )));
    }
    let mut body = vec![0u8; len];
    r.read_exact(&mut body)?;
    decode_body(&body)
}

/// Exchange `Hello` frames on a fresh connection (each side calls this
/// once, sending first): refuses a peer speaking another version.
pub fn handshake(stream: &mut (impl Read + Write)) -> MpResult<()> {
    write_frame(stream, &Frame::Hello {
        version: WIRE_VERSION,
    })?;
    match read_frame(stream)? {
        Frame::Hello { version } if version == WIRE_VERSION => Ok(()),
        Frame::Hello { version } => Err(wire_err(format!(
            "peer speaks wire version {version}, this build speaks {WIRE_VERSION}"
        ))),
        _ => Err(wire_err("peer did not open with Hello")),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn round_trip(f: &Frame) -> Frame {
        let bytes = encode_frame(f);
        let mut cursor = std::io::Cursor::new(bytes);
        read_frame(&mut cursor).expect("round trip decodes")
    }

    fn sample_request() -> WireRequest {
        WireRequest {
            id: 7,
            session: 42,
            timestamp: 1337,
            deadline_us: 50_000,
            payload: ServingPayload::Frame(ImageFrame::new(2, 2, 1, vec![0.0, 0.25, 0.5, 1.0])),
        }
    }

    fn sample_dets() -> Detections {
        vec![
            Detection {
                bbox: Rect::new(0.1, 0.2, 0.3, 0.4),
                score: 0.9,
                class_id: 3,
                track_id: Some(77),
            },
            Detection::new(Rect::new(0.5, 0.5, 0.1, 0.1), 0.6, 0),
        ]
    }

    #[test]
    fn request_round_trips_with_timestamp_and_deadline() {
        let req = sample_request();
        match round_trip(&Frame::Request(req.clone())) {
            Frame::Request(got) => assert_eq!(got, req),
            other => panic!("wrong frame: {other:?}"),
        }
    }

    #[test]
    fn take_payload_moves_without_cloning() {
        let mut req = sample_request();
        match req.take_payload() {
            ServingPayload::Frame(img) => {
                assert_eq!((img.width, img.height, img.channels), (2, 2, 1));
                assert_eq!(img.data.as_slice(), &[0.0, 0.25, 0.5, 1.0]);
            }
            other => panic!("wrong payload: {other:?}"),
        }
        // The header stays readable for reply correlation; the payload
        // slot is the cheap empty sentinel.
        assert_eq!(req.id, 7);
        assert_eq!(req.payload, ServingPayload::Tensor(Vec::new()));
    }

    #[test]
    fn frame_payload_dims_are_validated_on_decode() {
        // Corrupt the encoded pixel-count field so width*height*channels
        // no longer matches it: the decoder must return a typed error,
        // not feed mismatched dims to ImageFrame::new (which asserts).
        let mut body = encode_frame(&Frame::Request(sample_request()))[4..].to_vec();
        let count_at = REQUEST_OVERHEAD + 1 + 4 + 4 + 4;
        body[count_at..count_at + 4].copy_from_slice(&3u32.to_le_bytes());
        assert!(decode_body(&body).is_err());
        // Zero-area frames are refused too.
        for dim_at in [
            REQUEST_OVERHEAD + 1,
            REQUEST_OVERHEAD + 1 + 4,
            REQUEST_OVERHEAD + 1 + 8,
        ] {
            let mut zeroed = encode_frame(&Frame::Request(sample_request()))[4..].to_vec();
            zeroed[dim_at..dim_at + 4].copy_from_slice(&0u32.to_le_bytes());
            zeroed[count_at..count_at + 4].copy_from_slice(&0u32.to_le_bytes());
            assert!(decode_body(&zeroed).is_err());
        }
    }

    #[test]
    fn every_payload_variant_round_trips() {
        let payloads = vec![
            ServingPayload::Tensor(vec![1.0, -2.5, 0.0]),
            ServingPayload::Tensor(Vec::new()),
            ServingPayload::Detections(sample_dets()),
            ServingPayload::Detections(Vec::new()),
            ServingPayload::Landmarks(LandmarkList {
                points: vec![(0.1, 0.2), (0.3, 0.4)],
            }),
            ServingPayload::Map(vec![
                (
                    "pose".into(),
                    ServingPayload::Landmarks(LandmarkList {
                        points: vec![(0.5, 0.5)],
                    }),
                ),
                (
                    "angles".into(),
                    ServingPayload::Map(vec![(
                        "left_elbow".into(),
                        ServingPayload::Tensor(vec![1.57]),
                    )]),
                ),
            ]),
        ];
        for payload in payloads {
            let req = WireRequest {
                payload: payload.clone(),
                ..sample_request()
            };
            match round_trip(&Frame::Request(req)) {
                Frame::Request(got) => assert_eq!(got.payload, payload),
                other => panic!("wrong frame: {other:?}"),
            }
            let reply = Frame::Reply(WireReply {
                id: 9,
                session: 42,
                timestamp: 5,
                result: Ok(payload.clone()),
            });
            match round_trip(&reply) {
                Frame::Reply(got) => assert_eq!(got.result.unwrap(), payload),
                other => panic!("wrong frame: {other:?}"),
            }
        }
    }

    #[test]
    fn map_nesting_is_bounded_on_decode() {
        // One level past MAX_PAYLOAD_DEPTH must decode to an error; at
        // the bound it round-trips (encode has no depth limit — the
        // bound protects the decoder's stack from crafted bodies).
        let deep = |levels: usize| {
            let mut p = ServingPayload::Tensor(vec![1.0]);
            for _ in 0..levels {
                p = ServingPayload::Map(vec![("inner".into(), p)]);
            }
            p
        };
        let ok = Frame::Reply(WireReply {
            id: 1,
            session: 2,
            timestamp: 3,
            result: Ok(deep(MAX_PAYLOAD_DEPTH)),
        });
        match round_trip(&ok) {
            Frame::Reply(r) => assert!(r.result.is_ok()),
            other => panic!("wrong frame: {other:?}"),
        }
        let bomb = encode_frame(&Frame::Reply(WireReply {
            id: 1,
            session: 2,
            timestamp: 3,
            result: Ok(deep(MAX_PAYLOAD_DEPTH + 1)),
        }));
        assert!(decode_body(&bomb[4..]).is_err());
    }

    #[test]
    fn unknown_payload_tags_are_rejected() {
        let mut body = encode_frame(&Frame::Request(sample_request()))[4..].to_vec();
        body[REQUEST_OVERHEAD] = 0xEE;
        assert!(decode_body(&body).is_err());
    }

    #[test]
    fn ok_reply_round_trips_detections() {
        let dets = sample_dets();
        let reply = Frame::Reply(WireReply {
            id: 9,
            session: 42,
            timestamp: 5,
            result: Ok(ServingPayload::Detections(dets.clone())),
        });
        match round_trip(&reply) {
            Frame::Reply(got) => {
                assert_eq!(got.id, 9);
                assert_eq!(got.session, 42);
                assert_eq!(got.timestamp, 5);
                assert_eq!(got.result.unwrap(), ServingPayload::Detections(dets));
            }
            other => panic!("wrong frame: {other:?}"),
        }
    }

    #[test]
    fn typed_errors_round_trip_field_for_field() {
        let cases = vec![
            MpError::Overloaded {
                queued: 17,
                estimated_wait_us: 42_000,
            },
            MpError::DeadlineExceeded { waited_us: 9_000 },
            MpError::TimestampViolation {
                stream: "session-42".into(),
                packet_ts: 6,
                bound: 7,
            },
            MpError::WorkerLost {
                worker: "127.0.0.1:9901".into(),
            },
        ];
        for err in cases {
            let reply = Frame::Reply(WireReply {
                id: 1,
                session: 2,
                timestamp: 3,
                result: Err(err.clone()),
            });
            let got = match round_trip(&reply) {
                Frame::Reply(r) => r.result.unwrap_err(),
                other => panic!("wrong frame: {other:?}"),
            };
            match (&err, &got) {
                (
                    MpError::Overloaded {
                        queued: a,
                        estimated_wait_us: b,
                    },
                    MpError::Overloaded {
                        queued: c,
                        estimated_wait_us: d,
                    },
                ) => assert_eq!((a, b), (c, d)),
                (
                    MpError::DeadlineExceeded { waited_us: a },
                    MpError::DeadlineExceeded { waited_us: b },
                ) => assert_eq!(a, b),
                (
                    MpError::TimestampViolation {
                        stream: s1,
                        packet_ts: t1,
                        bound: b1,
                    },
                    MpError::TimestampViolation {
                        stream: s2,
                        packet_ts: t2,
                        bound: b2,
                    },
                ) => assert_eq!((s1, t1, b1), (s2, t2, b2)),
                (MpError::WorkerLost { worker: a }, MpError::WorkerLost { worker: b }) => {
                    assert_eq!(a, b)
                }
                (want, got) => panic!("variant changed over the wire: {want:?} -> {got:?}"),
            }
        }
    }

    #[test]
    fn untyped_errors_degrade_to_their_display_string() {
        let reply = Frame::Reply(WireReply {
            id: 1,
            session: 2,
            timestamp: 3,
            result: Err(MpError::Validation("bad config".into())),
        });
        match round_trip(&reply) {
            Frame::Reply(r) => match r.result.unwrap_err() {
                MpError::Runtime(msg) => assert!(msg.contains("bad config")),
                other => panic!("expected Runtime, got {other:?}"),
            },
            other => panic!("wrong frame: {other:?}"),
        }
    }

    #[test]
    fn health_and_metrics_frames_round_trip() {
        let stats = WorkerStats {
            requests: 1,
            errors: 2,
            shed: 3,
            expired: 4,
            sessions: 5,
        };
        match round_trip(&Frame::HealthPong { nonce: 99, stats }) {
            Frame::HealthPong { nonce, stats: got } => {
                assert_eq!(nonce, 99);
                assert_eq!(got, stats);
            }
            other => panic!("wrong frame: {other:?}"),
        }
        match round_trip(&Frame::HealthPing { nonce: 4 }) {
            Frame::HealthPing { nonce: 4 } => {}
            other => panic!("wrong frame: {other:?}"),
        }
        match round_trip(&Frame::MetricsReport {
            text: "requests=5".into(),
        }) {
            Frame::MetricsReport { text } => assert_eq!(text, "requests=5"),
            other => panic!("wrong frame: {other:?}"),
        }
        assert!(matches!(
            round_trip(&Frame::MetricsRequest),
            Frame::MetricsRequest
        ));
        match round_trip(&Frame::Goodbye {
            reason: "drain".into(),
        }) {
            Frame::Goodbye { reason } => assert_eq!(reason, "drain"),
            other => panic!("wrong frame: {other:?}"),
        }
    }

    #[test]
    fn truncated_and_garbage_frames_are_rejected() {
        // Truncated body: declared length longer than the bytes present.
        let mut bytes = encode_frame(&Frame::HealthPing { nonce: 1 });
        bytes.truncate(bytes.len() - 2);
        let mut cursor = std::io::Cursor::new(bytes);
        assert!(read_frame(&mut cursor).is_err());
        // Unknown tag.
        let body = vec![0xEEu8, 0, 0, 0];
        assert!(decode_body(&body).is_err());
        // Trailing bytes after a valid frame body.
        let mut body = encode_frame(&Frame::MetricsRequest)[4..].to_vec();
        body.push(0);
        assert!(decode_body(&body).is_err());
        // Oversized declared length is refused before allocation.
        let mut huge = Vec::new();
        put_u32(&mut huge, (MAX_FRAME_LEN + 1) as u32);
        let mut cursor = std::io::Cursor::new(huge);
        assert!(read_frame(&mut cursor).is_err());
    }

    #[test]
    fn oversized_frames_are_refused_on_the_send_side() {
        // One pixel past the bound tips the body over MAX_FRAME_LEN;
        // write_frame must error with zero bytes written, keeping the
        // connection usable.
        let n = MAX_REQUEST_PIXELS + 1;
        let req = WireRequest {
            payload: ServingPayload::Frame(ImageFrame::new(n, 1, 1, vec![0.0; n])),
            ..sample_request()
        };
        let mut sink = Vec::new();
        assert!(write_frame(&mut sink, &Frame::Request(req)).is_err());
        assert!(sink.is_empty(), "no bytes may reach the socket");
        // At the bound exactly, the frame is legal on both sides.
        let body_len = REQUEST_BODY_OVERHEAD + 4 * MAX_REQUEST_PIXELS;
        assert!(body_len <= MAX_FRAME_LEN);
    }

    #[test]
    fn handshake_agrees_on_version() {
        // Two in-memory peers: a duplex pair built from two buffers.
        // Cursor-based: write each side's Hello, then feed it to the
        // other side's reader.
        let hello = encode_frame(&Frame::Hello {
            version: WIRE_VERSION,
        });
        let mut cursor = std::io::Cursor::new(hello);
        match read_frame(&mut cursor).unwrap() {
            Frame::Hello { version } => assert_eq!(version, WIRE_VERSION),
            other => panic!("wrong frame: {other:?}"),
        }
        let stale = encode_frame(&Frame::Hello {
            version: WIRE_VERSION + 1,
        });
        let mut cursor = std::io::Cursor::new(stale);
        match read_frame(&mut cursor).unwrap() {
            Frame::Hello { version } => assert_ne!(version, WIRE_VERSION),
            other => panic!("wrong frame: {other:?}"),
        }
    }
}
