//! The serving perception pipeline: the calculators and graph config
//! that turn one batched detection request into detections **inside a
//! real MediaPipe graph** (preprocess → inference → postprocess), rather
//! than by calling the inference engine directly.
//!
//! One graph-input packet carries one dynamic batch ([`BatchFrames`]).
//! The preprocess node pads it to the nearest compiled detector variant,
//! the inference node executes that variant through the shared
//! [`InferenceEngine`], and the postprocess node decodes per-request
//! [`Detections`]. Because the request path is a graph run, everything
//! the framework provides — scheduler priorities, shared executors,
//! tracing — applies to serving traffic too: each node run is a push
//! into a scheduler queue registered with the server's (sharded, by
//! default) executor, so `benches/micro_hotpath.rs` measures per-packet
//! dispatch cost through exactly this path.

use std::sync::OnceLock;

use crate::calculator::{Calculator, CalculatorContext, Contract, ProcessOutcome};
use crate::calculators::inference::TensorVec;
use crate::error::{MpError, MpResult};
use crate::graph::config::GraphConfig;
use crate::packet::PacketType;
use crate::perception::types::{non_max_suppression, Detection, Detections, Rect};
use crate::registry::CalculatorRegistry;
use crate::runtime::{InferenceEngine, Tensor};

/// One dynamic batch of preprocessed frames: each entry is a flattened
/// `input_size × input_size` grayscale tensor.
pub type BatchFrames = Vec<Vec<f32>>;

/// Batch geometry, carried beside the tensors so the postprocess node
/// can split padded model output back into per-request rows.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct BatchInfo {
    /// Real requests in the batch.
    pub rows: usize,
    /// Compiled variant the batch was padded to (`rows <= padded`).
    pub padded: usize,
}

/// Pads a [`BatchFrames`] input to the smallest compiled detector
/// variant and emits the stacked NHWC tensor plus [`BatchInfo`].
/// Side packet `VARIANTS`: sorted `Vec<usize>` of compiled batch sizes.
/// Option `input_size`: frame edge length the detector was compiled for.
pub struct ServingPreprocess {
    variants: Vec<usize>,
    input_size: usize,
}

impl Calculator for ServingPreprocess {
    fn open(&mut self, ctx: &mut CalculatorContext) -> MpResult<()> {
        self.variants = ctx.side_input_tag("VARIANTS")?.get::<Vec<usize>>()?.clone();
        if self.variants.is_empty() {
            return Err(MpError::Runtime("no compiled detector variants".into()));
        }
        self.input_size = ctx.options().int_or("input_size", 32) as usize;
        Ok(())
    }

    fn process(&mut self, ctx: &mut CalculatorContext) -> MpResult<ProcessOutcome> {
        let p = ctx.input(0);
        if p.is_empty() {
            return Ok(ProcessOutcome::Continue);
        }
        let frames = p.get::<BatchFrames>()?;
        let rows = frames.len();
        if rows == 0 {
            return Err(MpError::Runtime("empty request batch".into()));
        }
        let elems = self.input_size * self.input_size;
        for (i, f) in frames.iter().enumerate() {
            if f.len() != elems {
                return Err(MpError::Runtime(format!(
                    "frame {i}: {} elems, detector wants {elems}",
                    f.len()
                )));
            }
        }
        let padded = *self
            .variants
            .iter()
            .find(|&&v| v >= rows)
            .unwrap_or(self.variants.last().expect("non-empty"));
        if rows > padded {
            // The server clamps max_batch to the largest variant; this
            // guards misconfigured direct users of the calculator from
            // panicking in Tensor::new below.
            return Err(MpError::Runtime(format!(
                "batch of {rows} exceeds largest compiled detector variant {padded}"
            )));
        }
        let mut data = Vec::with_capacity(padded * elems);
        for f in frames {
            data.extend_from_slice(f);
        }
        while data.len() < padded * elems {
            // Replicate the last frame as padding.
            let start = data.len() - elems;
            data.extend_from_within(start..start + elems);
        }
        let tensor = Tensor::new(vec![padded, self.input_size, self.input_size, 1], data);
        let tensors: TensorVec = vec![tensor];
        ctx.output_now(0, tensors);
        ctx.output_now(1, BatchInfo { rows, padded });
        Ok(ProcessOutcome::Continue)
    }
}

/// Runs the compiled detector variant matching the incoming batch size
/// (`detector` for batch 1, `detector_bN` otherwise) on the shared
/// engine. Side packet `ENGINE`: [`InferenceEngine`].
pub struct ServingInference {
    engine: Option<InferenceEngine>,
}

impl Calculator for ServingInference {
    fn open(&mut self, ctx: &mut CalculatorContext) -> MpResult<()> {
        self.engine = Some(ctx.side_input_tag("ENGINE")?.get::<InferenceEngine>()?.clone());
        Ok(())
    }

    fn process(&mut self, ctx: &mut CalculatorContext) -> MpResult<ProcessOutcome> {
        let p = ctx.input(0);
        if p.is_empty() {
            return Ok(ProcessOutcome::Continue);
        }
        let tensors = p.get::<TensorVec>()?;
        let bs = tensors
            .first()
            .and_then(|t| t.shape.first())
            .copied()
            .ok_or_else(|| MpError::Runtime("inference input has no batch dim".into()))?;
        let model = if bs == 1 {
            "detector".to_string()
        } else {
            format!("detector_b{bs}")
        };
        let engine = self.engine.as_ref().expect("opened");
        let outputs = engine.infer(&model, tensors.clone())?;
        ctx.output_now(0, outputs);
        Ok(ProcessOutcome::Continue)
    }
}

/// Decodes padded detector output (`boxes`, `scores`) into one
/// [`Detections`] list per real request row (threshold + NMS).
/// Options: `min_score` (0.5), `iou_threshold` (0.4).
pub struct ServingPostprocess {
    min_score: f32,
    iou_thr: f32,
}

impl Calculator for ServingPostprocess {
    fn open(&mut self, ctx: &mut CalculatorContext) -> MpResult<()> {
        let o = ctx.options();
        self.min_score = o.float_or("min_score", 0.5) as f32;
        self.iou_thr = o.float_or("iou_threshold", 0.4) as f32;
        Ok(())
    }

    fn process(&mut self, ctx: &mut CalculatorContext) -> MpResult<ProcessOutcome> {
        let tp = ctx.input(0);
        let ip = ctx.input(1);
        if tp.is_empty() || ip.is_empty() {
            return Ok(ProcessOutcome::Continue);
        }
        let tensors = tp.get::<TensorVec>()?;
        let info = *ip.get::<BatchInfo>()?;
        if tensors.len() < 2 {
            return Err(MpError::internal(
                "ServingPostprocess expects [boxes, scores]",
            ));
        }
        let (boxes, scores) = (&tensors[0], &tensors[1]);
        if info.padded == 0 || scores.data.len() % info.padded != 0 {
            return Err(MpError::internal(format!(
                "scores len {} not divisible by padded batch {}",
                scores.data.len(),
                info.padded
            )));
        }
        let n = scores.data.len() / info.padded;
        if boxes.data.len() != scores.data.len() * 4 {
            return Err(MpError::internal(format!(
                "boxes/scores mismatch: {} vs {}",
                boxes.data.len(),
                scores.data.len()
            )));
        }
        let mut per_row: Vec<Detections> = Vec::with_capacity(info.rows);
        for row in 0..info.rows {
            let mut dets: Detections = Vec::new();
            for i in 0..n {
                let s = scores.data[row * n + i];
                if s >= self.min_score {
                    let o = (row * n + i) * 4;
                    let b = &boxes.data[o..o + 4];
                    dets.push(Detection::new(
                        Rect::new(b[0], b[1], b[2], b[3]).clamped(),
                        s,
                        0,
                    ));
                }
            }
            per_row.push(non_max_suppression(dets, self.iou_thr));
        }
        ctx.output_now(0, per_row);
        Ok(ProcessOutcome::Continue)
    }
}

/// Turns a [`BatchFrames`] batch directly into one [`Detections`] row
/// per request, no model involved: each row yields a single detection
/// whose **score is the row's leading element**, so payloads round-trip
/// exactly and cross-request mixing is detectable. A **negative**
/// leading element fails the calculator — the deterministic poison hook
/// for error-path tests. Used by `benches/serving_pipelined.rs` and the
/// pipelining integration tests via
/// [`crate::serving::ServerConfig::graph_name`]; never part of the
/// real detector pipeline.
pub struct ServingEcho;

impl Calculator for ServingEcho {
    fn process(&mut self, ctx: &mut CalculatorContext) -> MpResult<ProcessOutcome> {
        let p = ctx.input(0);
        if p.is_empty() {
            return Ok(ProcessOutcome::Continue);
        }
        let frames = p.get::<BatchFrames>()?;
        let mut per_row: Vec<Detections> = Vec::with_capacity(frames.len());
        for (i, f) in frames.iter().enumerate() {
            let lead = f.first().copied().unwrap_or(0.0);
            if lead < 0.0 {
                return Err(MpError::Runtime(format!(
                    "poisoned frame in row {i} (leading element {lead})"
                )));
            }
            per_row.push(vec![Detection::new(
                Rect::new(0.25, 0.25, 0.5, 0.5),
                lead,
                0,
            )]);
        }
        ctx.output_now(0, per_row);
        Ok(ProcessOutcome::Continue)
    }
}

/// A deliberately **stage-imbalanced** serving graph for pipelining
/// benches/tests: `frames` flows through one `BusyWorkCalculator` per
/// entry of `stage_work_us` (each burning that much CPU per batch), then
/// [`ServingEcho`] decodes rows. With K timestamps in flight the graph
/// pipelines — stage `i` works on batch `t+1` while stage `i+1` works on
/// `t` — so steady-state throughput approaches the *slowest* stage's
/// rate instead of the sum of stages. No side packets, no model.
pub fn staged_pipeline_config(
    stage_work_us: &[u64],
    input_queue: Option<usize>,
) -> MpResult<GraphConfig> {
    let mut text = String::from("input_stream: \"frames\"\noutput_stream: \"detections\"\n");
    if let Some(n) = input_queue {
        text.push_str(&format!("input_queue_size: {n}\n"));
    }
    text.push_str("profiler { enabled: true buffer_size: 8192 }\n");
    let mut src = "frames".to_string();
    for (i, us) in stage_work_us.iter().enumerate() {
        let dst = format!("stage{i}");
        text.push_str(&format!(
            "node {{ calculator: \"BusyWorkCalculator\" input_stream: \"{src}\" output_stream: \"{dst}\" options {{ work_us: {us} }} }}\n"
        ));
        src = dst;
    }
    text.push_str(&format!(
        "node {{ calculator: \"ServingEchoCalculator\" input_stream: \"FRAMES:{src}\" output_stream: \"DETS:detections\" }}\n"
    ));
    GraphConfig::parse(&text)
}

/// Register the serving calculators in `r`.
pub fn register(r: &CalculatorRegistry) {
    r.register_fn(
        "ServingPreprocessCalculator",
        |_| {
            Ok(Contract::new()
                .input("FRAMES", PacketType::of::<BatchFrames>())
                .output("TENSORS", PacketType::of::<TensorVec>())
                .output("INFO", PacketType::of::<BatchInfo>())
                .side_input("VARIANTS", PacketType::of::<Vec<usize>>())
                .with_timestamp_offset(0))
        },
        |_| {
            Ok(Box::new(ServingPreprocess {
                variants: Vec::new(),
                input_size: 32,
            }))
        },
    );
    r.register_fn(
        "ServingInferenceCalculator",
        |_| {
            Ok(Contract::new()
                .input("TENSORS", PacketType::of::<TensorVec>())
                .output("TENSORS", PacketType::of::<TensorVec>())
                .side_input("ENGINE", PacketType::of::<InferenceEngine>())
                .with_timestamp_offset(0))
        },
        |_| Ok(Box::new(ServingInference { engine: None })),
    );
    r.register_fn(
        "ServingEchoCalculator",
        |_| {
            Ok(Contract::new()
                .input("FRAMES", PacketType::of::<BatchFrames>())
                .output("DETS", PacketType::of::<Vec<Detections>>())
                .with_timestamp_offset(0))
        },
        |_| Ok(Box::new(ServingEcho)),
    );
    r.register_fn(
        "ServingPostprocessCalculator",
        |_| {
            Ok(Contract::new()
                .input("TENSORS", PacketType::of::<TensorVec>())
                .input("INFO", PacketType::of::<BatchInfo>())
                .output("DETS", PacketType::of::<Vec<Detections>>())
                .with_timestamp_offset(0))
        },
        |_| {
            Ok(Box::new(ServingPostprocess {
                min_score: 0.5,
                iou_thr: 0.4,
            }))
        },
    );
}

/// Register the serving calculators in the global registry exactly once.
pub fn ensure_registered() {
    static ONCE: OnceLock<()> = OnceLock::new();
    ONCE.get_or_init(|| {
        register(CalculatorRegistry::global());
    });
}

/// The serving graph: preprocess → inference → postprocess, tracing
/// enabled so every request leaves tracer evidence of its graph run.
pub fn pipeline_config(input_size: usize, min_score: f32, iou_threshold: f32) -> MpResult<GraphConfig> {
    pipeline_config_impl(input_size, min_score, iou_threshold, None)
}

/// The same pipeline with an **admission bound** on the `frames` input
/// (`input_queue_size`), for long-lived [`crate::serving::StreamingSession`]s:
/// at most `input_queue` batches buffer inside the graph before the
/// feeder's push blocks, so a slow model back-pressures the batcher
/// instead of queueing unboundedly.
pub fn streaming_pipeline_config(
    input_size: usize,
    min_score: f32,
    iou_threshold: f32,
    input_queue: usize,
) -> MpResult<GraphConfig> {
    pipeline_config_impl(input_size, min_score, iou_threshold, Some(input_queue))
}

fn pipeline_config_impl(
    input_size: usize,
    min_score: f32,
    iou_threshold: f32,
    input_queue: Option<usize>,
) -> MpResult<GraphConfig> {
    let input_bound = match input_queue {
        Some(n) => format!("input_queue_size: {n}\n"),
        None => String::new(),
    };
    let text = format!(
        r#"
input_stream: "frames"
output_stream: "detections"
input_side_packet: "engine"
input_side_packet: "variants"
{input_bound}profiler {{ enabled: true buffer_size: 8192 }}
node {{
  calculator: "ServingPreprocessCalculator"
  input_stream: "FRAMES:frames"
  output_stream: "TENSORS:tensors"
  output_stream: "INFO:batch_info"
  input_side_packet: "VARIANTS:variants"
  options {{ input_size: {input_size} }}
}}
node {{
  calculator: "ServingInferenceCalculator"
  input_stream: "TENSORS:tensors"
  output_stream: "TENSORS:raw"
  input_side_packet: "ENGINE:engine"
}}
node {{
  calculator: "ServingPostprocessCalculator"
  input_stream: "TENSORS:raw"
  input_stream: "INFO:batch_info"
  output_stream: "DETS:detections"
  options {{ min_score: {min_score} iou_threshold: {iou_threshold} }}
}}
"#
    );
    GraphConfig::parse(&text)
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The single test-scoped bound on graph output, playing the role
    /// `ServerConfig::batch_timeout` plays on the serving path (these
    /// tests drive graphs directly — no server, so no live config to
    /// read). Tighter than the 60 s production default: a wedged graph
    /// fails the test in seconds.
    const OUTPUT_TIMEOUT: std::time::Duration = std::time::Duration::from_secs(15);

    #[test]
    fn pipeline_config_parses_and_plans() {
        ensure_registered();
        let cfg = pipeline_config(8, 0.5, 0.4).unwrap();
        assert_eq!(cfg.nodes.len(), 3);
        assert!(cfg.profiler.enabled);
        // plans cleanly against the global registry
        let g = crate::graph::Graph::new(&cfg).unwrap();
        assert_eq!(g.node_names().len(), 3);
    }

    #[test]
    fn streaming_config_bounds_the_input_stream() {
        ensure_registered();
        let cfg = streaming_pipeline_config(8, 0.5, 0.4, 3).unwrap();
        assert_eq!(cfg.input_queue_size, Some(3));
        let g = crate::graph::Graph::new(&cfg).unwrap();
        assert_eq!(g.node_names().len(), 3);
        // The unbounded config stays unbounded.
        assert_eq!(pipeline_config(8, 0.5, 0.4).unwrap().input_queue_size, None);
    }

    #[test]
    fn staged_config_parses_and_plans() {
        ensure_registered();
        let cfg = staged_pipeline_config(&[50, 200, 50], Some(8)).unwrap();
        assert_eq!(cfg.nodes.len(), 4, "three busy stages + echo");
        assert_eq!(cfg.input_queue_size, Some(8));
        let g = crate::graph::Graph::new(&cfg).unwrap();
        assert_eq!(g.node_names().len(), 4);
        // No stages degenerates to the echo alone, unbounded.
        let bare = staged_pipeline_config(&[], None).unwrap();
        assert_eq!(bare.nodes.len(), 1);
        assert_eq!(bare.input_queue_size, None);
    }

    #[test]
    fn echo_round_trips_payloads_and_rejects_poison() {
        ensure_registered();
        let cfg = staged_pipeline_config(&[], None).unwrap();
        let mut g = crate::graph::Graph::new(&cfg).unwrap();
        let poller = g.poller("detections").unwrap();
        g.start_run(crate::graph::SidePackets::new()).unwrap();
        let frames: BatchFrames = vec![vec![0.25; 4], vec![0.75; 4]];
        g.add_packet(
            "frames",
            crate::packet::Packet::new(frames, crate::timestamp::Timestamp::new(0)),
        )
        .unwrap();
        g.close_all_inputs().unwrap();
        let out = match poller.poll(OUTPUT_TIMEOUT) {
            crate::graph::Poll::Packet(p) => p.get::<Vec<Detections>>().unwrap().clone(),
            other => panic!("expected echo output, got {other:?}"),
        };
        g.wait_until_done().unwrap();
        assert_eq!(out.len(), 2, "one detections row per request");
        assert!((out[0][0].score - 0.25).abs() < 1e-6);
        assert!((out[1][0].score - 0.75).abs() < 1e-6);
        // A negative leading element is the poison hook: the run fails.
        let mut g = crate::graph::Graph::new(&cfg).unwrap();
        g.start_run(crate::graph::SidePackets::new()).unwrap();
        let poisoned: BatchFrames = vec![vec![-1.0; 4]];
        g.add_packet(
            "frames",
            crate::packet::Packet::new(poisoned, crate::timestamp::Timestamp::new(0)),
        )
        .unwrap();
        g.close_all_inputs().unwrap();
        assert!(g.wait_until_done().is_err(), "poisoned batch fails the run");
    }

    #[test]
    fn preprocess_pads_to_variant() {
        // Exercise the padding math directly (no graph needed).
        let pre = ServingPreprocess {
            variants: vec![1, 4],
            input_size: 2,
        };
        // Mimic process() inner logic through a tiny harness: 3 frames
        // of 4 elems -> padded to variant 4 by replicating the last.
        let frames: BatchFrames = vec![vec![1.0; 4], vec![2.0; 4], vec![3.0; 4]];
        let rows = frames.len();
        let elems = pre.input_size * pre.input_size;
        let padded = *pre
            .variants
            .iter()
            .find(|&&v| v >= rows)
            .unwrap_or(pre.variants.last().unwrap());
        assert_eq!(padded, 4);
        let mut data = Vec::new();
        for f in &frames {
            data.extend_from_slice(f);
        }
        while data.len() < padded * elems {
            let start = data.len() - elems;
            data.extend_from_within(start..start + elems);
        }
        assert_eq!(data.len(), 16);
        assert_eq!(&data[12..16], &[3.0; 4], "padding replicates last frame");
    }

    #[test]
    fn postprocess_splits_rows_and_thresholds() {
        let post = ServingPostprocess {
            min_score: 0.5,
            iou_thr: 0.4,
        };
        // padded=2 rows=1, n=2 anchors: row 0 has one passing score.
        let boxes = Tensor::new(
            vec![4, 4],
            vec![
                0.1, 0.1, 0.2, 0.2, // row0 a0: .9
                0.6, 0.6, 0.2, 0.2, // row0 a1: .2 (below)
                0.3, 0.3, 0.2, 0.2, // row1 (padding)
                0.4, 0.4, 0.2, 0.2, // row1 (padding)
            ],
        );
        let scores = Tensor::new(vec![4], vec![0.9, 0.2, 0.8, 0.8]);
        let info = BatchInfo { rows: 1, padded: 2 };
        let n = scores.data.len() / info.padded;
        assert_eq!(n, 2);
        let mut per_row: Vec<Detections> = Vec::new();
        for row in 0..info.rows {
            let mut dets: Detections = Vec::new();
            for i in 0..n {
                let s = scores.data[row * n + i];
                if s >= post.min_score {
                    let o = (row * n + i) * 4;
                    let b = &boxes.data[o..o + 4];
                    dets.push(Detection::new(Rect::new(b[0], b[1], b[2], b[3]), s, 0));
                }
            }
            per_row.push(non_max_suppression(dets, post.iou_thr));
        }
        assert_eq!(per_row.len(), 1, "padding rows are not decoded");
        assert_eq!(per_row[0].len(), 1);
        assert!((per_row[0][0].score - 0.9).abs() < 1e-6);
    }
}
