//! The typed serving **payload seam**: one value representation every
//! serving layer routes — batcher, streaming sessions, wire format,
//! socket worker, router and CLI — so the data plane is no longer
//! hard-wired to `ImageFrame` in / `Detections` out.
//!
//! Two pieces:
//!
//! * [`ServingPayload`] — the closed set of values a request or reply
//!   can carry: an image frame, a flat f32 tensor, a detection list, a
//!   landmark list, or a named map of payloads (multi-output graphs,
//!   and domain types such as joint angles that decompose into named
//!   parts). Zero-dependency by construction: every variant is built
//!   from crate-owned types.
//! * [`IoDescriptor`] — the per-[`crate::serving::GraphVersion`] I/O
//!   contract: which input stream a served graph consumes (and as what
//!   payload kind), which output streams it produces (and as what
//!   kinds), and whether it speaks the *batched* detector shape (one
//!   packet = a `Vec` of per-request tensors, one output packet = a
//!   `Vec` of per-request detection rows) or the *per-frame* shape (one
//!   packet per request timestamp). Descriptors are **inferred from the
//!   validated plan** — the declared [`crate::packet::PacketType`]s of
//!   the graph's input consumers and output producers — so they are
//!   computed exactly once, at `register`/`swap` time, never on the
//!   request path.
//!
//! Stream types the data plane cannot convert infer as
//! [`PayloadKind::Opaque`]. Registration tolerates them (the registry
//! also hosts generic graphs that are never served), but
//! [`IoDescriptor::ensure_servable`] — called by
//! [`crate::serving::PipelineServer::start`] — rejects them with a
//! typed validation error before any traffic flows.

use crate::calculators::scenarios::{HolisticResult, JointAngles};
use crate::error::{MpError, MpResult};
use crate::graph::GraphConfig;
use crate::packet::{Packet, PacketType};
use crate::perception::types::{Detections, LandmarkList};
use crate::perception::ImageFrame;
use crate::serving::pipeline::BatchFrames;
use crate::timestamp::Timestamp;

/// One typed value crossing the serving data plane — submitted as a
/// request or returned as a result, in-process or over the wire.
#[derive(Clone, Debug)]
pub enum ServingPayload {
    /// An image frame (HWC f32, as [`ImageFrame`]).
    Frame(ImageFrame),
    /// A flat f32 vector (a preprocessed tensor row).
    Tensor(Vec<f32>),
    /// A detection list.
    Detections(Detections),
    /// A landmark list.
    Landmarks(LandmarkList),
    /// A named multi-output map: one entry per named part, in a stable
    /// declared order. Multi-output graphs resolve to one `Map` per
    /// timestamp (stream name → that stream's payload); domain types
    /// such as [`JointAngles`] decompose into named entries.
    Map(Vec<(String, ServingPayload)>),
}

impl PartialEq for ServingPayload {
    fn eq(&self, other: &ServingPayload) -> bool {
        match (self, other) {
            (ServingPayload::Frame(a), ServingPayload::Frame(b)) => {
                a.width == b.width
                    && a.height == b.height
                    && a.channels == b.channels
                    && a.data.as_slice() == b.data.as_slice()
            }
            (ServingPayload::Tensor(a), ServingPayload::Tensor(b)) => a == b,
            (ServingPayload::Detections(a), ServingPayload::Detections(b)) => a == b,
            (ServingPayload::Landmarks(a), ServingPayload::Landmarks(b)) => {
                a.points == b.points
            }
            (ServingPayload::Map(a), ServingPayload::Map(b)) => a == b,
            _ => false,
        }
    }
}

impl ServingPayload {
    /// The kind tag of this value.
    pub fn kind(&self) -> PayloadKind {
        match self {
            ServingPayload::Frame(_) => PayloadKind::Frame,
            ServingPayload::Tensor(_) => PayloadKind::Tensor,
            ServingPayload::Detections(_) => PayloadKind::Detections,
            ServingPayload::Landmarks(_) => PayloadKind::Landmarks,
            ServingPayload::Map(_) => PayloadKind::Map,
        }
    }

    /// Short human-readable shape summary (CLI / error messages).
    pub fn summary(&self) -> String {
        match self {
            ServingPayload::Frame(f) => {
                format!("frame({}x{}x{})", f.width, f.height, f.channels)
            }
            ServingPayload::Tensor(t) => format!("tensor({})", t.len()),
            ServingPayload::Detections(d) => format!("detections({})", d.len()),
            ServingPayload::Landmarks(l) => format!("landmarks({} pts)", l.points.len()),
            ServingPayload::Map(m) => {
                let names: Vec<&str> = m.iter().map(|(n, _)| n.as_str()).collect();
                format!("map({})", names.join(","))
            }
        }
    }

    /// Look up a named entry of a [`ServingPayload::Map`].
    pub fn entry(&self, name: &str) -> Option<&ServingPayload> {
        match self {
            ServingPayload::Map(m) => m.iter().find(|(n, _)| n == name).map(|(_, p)| p),
            _ => None,
        }
    }

    /// Convert a graph result packet into a payload, by the packet's
    /// concrete type. Every type the catalog's calculators emit (plus
    /// an already-assembled `ServingPayload`, which multi-output
    /// session aggregation produces) converts; anything else is a typed
    /// mismatch naming the offending type.
    pub fn from_packet(pkt: &Packet) -> MpResult<ServingPayload> {
        if let Ok(p) = pkt.get::<ServingPayload>() {
            return Ok(p.clone());
        }
        if let Ok(d) = pkt.get::<Detections>() {
            return Ok(ServingPayload::Detections(d.clone()));
        }
        if let Ok(l) = pkt.get::<LandmarkList>() {
            return Ok(ServingPayload::Landmarks(l.clone()));
        }
        if let Ok(a) = pkt.get::<JointAngles>() {
            return Ok(ServingPayload::from_angles(a));
        }
        if let Ok(h) = pkt.get::<HolisticResult>() {
            return Ok(ServingPayload::from_holistic(h));
        }
        if let Ok(t) = pkt.get::<Vec<f32>>() {
            return Ok(ServingPayload::Tensor(t.clone()));
        }
        if let Ok(f) = pkt.get::<ImageFrame>() {
            return Ok(ServingPayload::Frame(f.clone()));
        }
        Err(MpError::PacketTypeMismatch {
            expected: "a serving payload type",
            actual: pkt.type_name(),
        })
    }

    /// Wrap this payload in an input packet at `ts`, as the concrete
    /// type a graph's input port expects ([`ServingPayload::Map`] stays
    /// wrapped — no calculator consumes it directly).
    pub fn into_packet(self, ts: Timestamp) -> Packet {
        match self {
            ServingPayload::Frame(f) => Packet::new(f, ts),
            ServingPayload::Tensor(t) => Packet::new(t, ts),
            ServingPayload::Detections(d) => Packet::new(d, ts),
            ServingPayload::Landmarks(l) => Packet::new(l, ts),
            map @ ServingPayload::Map(_) => Packet::new(map, ts),
        }
    }

    /// Unwrap into a detection list — the detector-era compat seam:
    /// `Detections`-typed handles ([`crate::serving::ServerHandle::detect`]
    /// and friends) funnel every result through here.
    pub fn into_detections(self) -> MpResult<Detections> {
        match self {
            ServingPayload::Detections(d) => Ok(d),
            other => Err(MpError::PacketTypeMismatch {
                expected: "detections",
                actual: other.kind().name(),
            }),
        }
    }

    /// Joint angles decompose into one named single-element tensor per
    /// joint, preserving the calculator's declared order.
    pub fn from_angles(a: &JointAngles) -> ServingPayload {
        ServingPayload::Map(
            a.angles
                .iter()
                .map(|(name, v)| (name.clone(), ServingPayload::Tensor(vec![*v])))
                .collect(),
        )
    }

    /// A holistic result decomposes into named landmark lists: `pose`,
    /// `hand_0..`, `face`.
    pub fn from_holistic(h: &HolisticResult) -> ServingPayload {
        let mut entries = Vec::with_capacity(2 + h.hands.len());
        entries.push((
            "pose".to_string(),
            ServingPayload::Landmarks(h.pose.clone()),
        ));
        for (i, hand) in h.hands.iter().enumerate() {
            entries.push((
                format!("hand_{i}"),
                ServingPayload::Landmarks(hand.clone()),
            ));
        }
        entries.push((
            "face".to_string(),
            ServingPayload::Landmarks(h.face.clone()),
        ));
        ServingPayload::Map(entries)
    }
}

/// The kind of payload a declared stream carries.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PayloadKind {
    /// [`ImageFrame`].
    Frame,
    /// `Vec<f32>`.
    Tensor,
    /// [`Detections`].
    Detections,
    /// [`LandmarkList`].
    Landmarks,
    /// A named multi-part value ([`ServingPayload::Map`]).
    Map,
    /// A stream type the serving data plane cannot convert. Tolerated
    /// at registration (generic registry entries), refused at serve
    /// time ([`IoDescriptor::ensure_servable`]).
    Opaque,
}

impl PayloadKind {
    /// Stable lower-case name (errors, CLI).
    pub fn name(&self) -> &'static str {
        match self {
            PayloadKind::Frame => "frame",
            PayloadKind::Tensor => "tensor",
            PayloadKind::Detections => "detections",
            PayloadKind::Landmarks => "landmarks",
            PayloadKind::Map => "map",
            PayloadKind::Opaque => "opaque",
        }
    }
}

/// The serving I/O contract of one validated graph version: declared
/// input/output stream names and payload kinds, plus whether the graph
/// speaks the batched detector shape (module docs). Inferred by
/// [`IoDescriptor::infer`] during [`crate::serving::GraphVersion`]
/// validation and frozen on the version.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct IoDescriptor {
    /// The graph input stream serving submits on (first declared input;
    /// empty for graphs with no input stream — never servable).
    pub input_stream: String,
    /// What one request submits on `input_stream`.
    pub input_kind: PayloadKind,
    /// Declared graph outputs in config order, each with the payload
    /// kind its producer emits. One output ⇒ results are that payload;
    /// several ⇒ results aggregate into a [`ServingPayload::Map`] keyed
    /// by stream name.
    pub outputs: Vec<(String, PayloadKind)>,
    /// Detector shape: the input packet carries a `Vec` of per-request
    /// tensors and the single output packet a `Vec` of per-request
    /// detection rows, so one graph timestamp serves a whole batch.
    /// Per-frame graphs (`false`) get one timestamp per request.
    pub batched: bool,
}

impl IoDescriptor {
    /// Derive the descriptor from an expanded config and the declared
    /// packet types of its port contracts. Input kinds come from the
    /// input stream's consumer contracts (graph-input streams carry no
    /// producer type in the plan), walking through type-erased
    /// pass-through stages to the first concretely typed port; output
    /// kinds come from the producing port recorded in the plan.
    pub fn infer(config: &GraphConfig, plan: &crate::graph::Plan) -> IoDescriptor {
        let input_stream = config
            .input_streams
            .first()
            .map(|b| b.name.clone())
            .unwrap_or_default();
        let input_type = plan
            .graph_inputs
            .get(&input_stream)
            .map(|&si| consumer_type(plan, si))
            .unwrap_or(PacketType::Any);
        let (input_kind, batched) = input_kind_of(&input_type);
        let outputs = plan
            .graph_outputs
            .iter()
            .map(|(name, si)| {
                (
                    name.clone(),
                    output_kind_of(&plan.streams[*si].packet_type, batched),
                )
            })
            .collect();
        IoDescriptor {
            input_stream,
            input_kind,
            outputs,
            batched,
        }
    }

    /// The detector pipeline's shape, for reference and tests.
    pub fn detector_default() -> IoDescriptor {
        IoDescriptor {
            input_stream: "frames".to_string(),
            input_kind: PayloadKind::Tensor,
            outputs: vec![("detections".to_string(), PayloadKind::Detections)],
            batched: true,
        }
    }

    /// The declared output stream names, in order.
    pub fn output_streams(&self) -> Vec<String> {
        self.outputs.iter().map(|(n, _)| n.clone()).collect()
    }

    /// The payload kind one resolved result carries: the single
    /// output's kind, or [`PayloadKind::Map`] for multi-output graphs.
    pub fn result_kind(&self) -> PayloadKind {
        match self.outputs.as_slice() {
            [(_, k)] => *k,
            _ => PayloadKind::Map,
        }
    }

    /// Can the serving data plane route this graph? Typed validation:
    /// an input stream must exist and convert, no output may be opaque,
    /// and the batched shape must be exactly the detector's.
    pub fn ensure_servable(&self) -> MpResult<()> {
        if self.input_stream.is_empty() {
            return Err(MpError::Validation(
                "serving: graph declares no input stream".into(),
            ));
        }
        if self.input_kind == PayloadKind::Opaque {
            return Err(MpError::Validation(format!(
                "serving: input stream '{}' has a type the data plane cannot \
                 carry (declare an image-frame or tensor input)",
                self.input_stream
            )));
        }
        if self.outputs.is_empty() {
            return Err(MpError::Validation(
                "serving: graph declares no output stream".into(),
            ));
        }
        if let Some((name, _)) = self
            .outputs
            .iter()
            .find(|(_, k)| *k == PayloadKind::Opaque)
        {
            return Err(MpError::Validation(format!(
                "serving: output stream '{name}' has a type the data plane \
                 cannot carry"
            )));
        }
        if self.batched
            && (self.outputs.len() != 1 || self.outputs[0].1 != PayloadKind::Detections)
        {
            return Err(MpError::Validation(
                "serving: a batched (detector-shaped) graph must declare \
                 exactly one per-row detections output"
                    .into(),
            ));
        }
        Ok(())
    }
}

/// The declared input type governing stream `si` (graph-input streams
/// have no producer, so consumer contracts are the only source of type
/// evidence). Type-erased pass-through stages — consumer ports declared
/// [`PacketType::Any`], e.g. `BusyWorkCalculator` busy-work chains —
/// are walked *through*: the search follows their output streams
/// downstream until a concretely typed consumer port is found. Cycles
/// (declared back edges) are bounded by the visited-stream set.
fn consumer_type(plan: &crate::graph::Plan, si: usize) -> PacketType {
    let mut seen = vec![false; plan.streams.len()];
    let mut frontier = vec![si];
    while !frontier.is_empty() {
        let mut next = Vec::new();
        for si in frontier {
            if std::mem::replace(&mut seen[si], true) {
                continue;
            }
            for &(ni, port) in &plan.streams[si].consumers {
                let node = &plan.nodes[ni];
                let t = node
                    .contract
                    .inputs
                    .get(port)
                    .map(|p| p.packet_type)
                    .unwrap_or(PacketType::Any);
                if !matches!(t, PacketType::Any) {
                    return t;
                }
                next.extend(
                    node.out_streams.iter().copied().filter(|&o| o != usize::MAX),
                );
            }
        }
        frontier = next;
    }
    PacketType::Any
}

fn is<T: std::any::Any + Send + Sync>(t: &PacketType) -> bool {
    matches!(t, PacketType::Of(id, _) if *id == std::any::TypeId::of::<T>())
}

/// Input-side kind mapping; `BatchFrames` marks the batched shape.
fn input_kind_of(t: &PacketType) -> (PayloadKind, bool) {
    if is::<BatchFrames>(t) {
        (PayloadKind::Tensor, true)
    } else if is::<ImageFrame>(t) {
        (PayloadKind::Frame, false)
    } else if is::<Vec<f32>>(t) {
        (PayloadKind::Tensor, false)
    } else {
        (PayloadKind::Opaque, false)
    }
}

/// Output-side kind mapping. The per-row `Vec<Detections>` shape is
/// only meaningful on a batched graph.
fn output_kind_of(t: &PacketType, batched: bool) -> PayloadKind {
    if batched && is::<Vec<Detections>>(t) {
        PayloadKind::Detections
    } else if is::<Detections>(t) {
        PayloadKind::Detections
    } else if is::<LandmarkList>(t) {
        PayloadKind::Landmarks
    } else if is::<JointAngles>(t) || is::<HolisticResult>(t) {
        PayloadKind::Map
    } else if is::<Vec<f32>>(t) {
        PayloadKind::Tensor
    } else if is::<ImageFrame>(t) {
        PayloadKind::Frame
    } else {
        PayloadKind::Opaque
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::perception::types::{Detection, Rect};

    #[test]
    fn payload_kinds_and_summaries() {
        let f = ServingPayload::Frame(ImageFrame::filled(2, 2, 1, 0.5));
        assert_eq!(f.kind(), PayloadKind::Frame);
        assert_eq!(f.summary(), "frame(2x2x1)");
        let t = ServingPayload::Tensor(vec![1.0, 2.0]);
        assert_eq!(t.kind(), PayloadKind::Tensor);
        let d = ServingPayload::Detections(vec![Detection::new(
            Rect::new(0.1, 0.1, 0.2, 0.2),
            0.9,
            0,
        )]);
        assert_eq!(d.kind(), PayloadKind::Detections);
        assert_eq!(d.summary(), "detections(1)");
        let m = ServingPayload::Map(vec![("a".into(), t.clone())]);
        assert_eq!(m.kind(), PayloadKind::Map);
        assert_eq!(m.entry("a"), Some(&t));
        assert_eq!(m.entry("b"), None);
    }

    #[test]
    fn packet_round_trip_by_concrete_type() {
        let lm = LandmarkList::new(vec![(0.1, 0.2), (0.3, 0.4)]);
        let pkt = Packet::new(lm.clone(), Timestamp::new(3));
        match ServingPayload::from_packet(&pkt).unwrap() {
            ServingPayload::Landmarks(got) => assert_eq!(got.points, lm.points),
            other => panic!("wrong variant: {other:?}"),
        }
        // An unconvertible packet is a typed mismatch naming the type.
        let pkt = Packet::new(7i64, Timestamp::new(0));
        match ServingPayload::from_packet(&pkt) {
            Err(MpError::PacketTypeMismatch { actual, .. }) => {
                assert!(actual.contains("i64"))
            }
            other => panic!("expected typed mismatch, got {other:?}"),
        }
    }

    #[test]
    fn angles_and_holistic_decompose_into_named_maps() {
        let a = JointAngles {
            angles: vec![("left_elbow".into(), 1.5), ("right_knee".into(), 0.7)],
        };
        let m = ServingPayload::from_angles(&a);
        assert_eq!(m.kind(), PayloadKind::Map);
        match m.entry("right_knee") {
            Some(ServingPayload::Tensor(v)) => assert_eq!(v.as_slice(), &[0.7]),
            other => panic!("wrong entry: {other:?}"),
        }
        let h = HolisticResult {
            pose: LandmarkList::new(vec![(0.5, 0.5)]),
            hands: vec![LandmarkList::new(vec![(0.1, 0.1)])],
            face: LandmarkList::new(vec![(0.9, 0.9)]),
        };
        let m = ServingPayload::from_holistic(&h);
        assert!(m.entry("pose").is_some());
        assert!(m.entry("hand_0").is_some());
        assert!(m.entry("face").is_some());
    }

    #[test]
    fn into_detections_is_the_compat_funnel() {
        let d = vec![Detection::new(Rect::new(0.0, 0.0, 0.1, 0.1), 0.8, 1)];
        assert_eq!(
            ServingPayload::Detections(d.clone()).into_detections().unwrap(),
            d
        );
        match ServingPayload::Tensor(vec![1.0]).into_detections() {
            Err(MpError::PacketTypeMismatch { actual, .. }) => {
                assert_eq!(actual, "tensor")
            }
            other => panic!("expected typed mismatch, got {other:?}"),
        }
    }

    #[test]
    fn servable_checks_are_typed() {
        let mut io = IoDescriptor::detector_default();
        io.ensure_servable().unwrap();
        io.input_kind = PayloadKind::Opaque;
        assert!(matches!(io.ensure_servable(), Err(MpError::Validation(_))));
        let mut io = IoDescriptor::detector_default();
        io.outputs.clear();
        assert!(io.ensure_servable().is_err());
        let mut io = IoDescriptor::detector_default();
        io.outputs.push(("extra".into(), PayloadKind::Landmarks));
        assert!(io.ensure_servable().is_err(), "batched graphs are single-output");
    }
}
