//! Long-lived **streaming sessions**: one graph instance serving many
//! successive requests as successive timestamps.
//!
//! The pooled serving path ([`crate::serving::GraphPool`]) checks out a
//! fresh graph per batch — strongest isolation, but every batch pays
//! graph build, `start_run` (Open on every node) and teardown. The
//! paper's own model is the opposite: a *long-running* graph consuming a
//! *stream* of timestamped packets. A [`StreamingSession`] serves that
//! model:
//!
//! * it owns one started [`PooledGraph`] for its whole life;
//! * each submitted request becomes the next **timestamp** on the
//!   graph's input stream, pushed through an [`InputHandle`]
//!   ([`InputHandle::push_final`], so the timestamp settles immediately
//!   and downstream nodes fire without waiting for the next request);
//! * results are **demultiplexed by timestamp**: an output-stream
//!   callback routes each result packet to the [`SessionTicket`] whose
//!   timestamp it carries, so any number of producer threads can have
//!   requests in flight concurrently with no cross-request mixing;
//! * after [`StreamingSession::max_timestamps`] submissions (or on
//!   error) the owner recycles the session: [`StreamingSession::finish`]
//!   closes the stream, drains the graph and checks the used instance
//!   back into its pool, which replaces it with a fresh build — the
//!   isolation story degrades from per-batch to per-session, bounded by
//!   the recycle interval.
//!
//! Timestamps are allocated (or validated) under one session lock, so
//! pushes enter the graph strictly monotonically; a stale or duplicate
//! explicit timestamp is rejected with a clean
//! [`MpError::TimestampViolation`] before it can poison the stream.
//!
//! Sessions are built for **pipelined** owners keeping many tickets in
//! flight: [`StreamingSession::set_result_notifier`] wakes the owner
//! when *any* ticket becomes resolvable, [`SessionTicket::try_wait`]
//! resolves ready tickets without blocking, and the
//! submitted-vs-resolved counters ([`StreamingSession::timestamps_submitted`]
//! / [`StreamingSession::timestamps_resolved`]) let the owner drain the
//! whole window before a planned recycle — see the K-deep window in
//! [`crate::serving`]'s module docs.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::Duration;

use crate::error::{MpError, MpResult};
use crate::graph::{InputHandle, SidePackets};
use crate::packet::Packet;
use crate::serving::payload::ServingPayload;
use crate::serving::pool::PooledGraph;
use crate::sync::lock_recover;
use crate::timestamp::Timestamp;

/// Called (outside any session lock on the waiter's side) every time a
/// ticket becomes resolvable — an owner driving many tickets can sleep
/// on one primitive instead of polling each ticket.
type ResultNotifier = Box<dyn Fn() + Send + Sync>;

/// The demultiplexer shared between a session and its graph's
/// output-stream callback: per-timestamp reply routing plus the
/// submitted-vs-resolved evidence counters.
struct Demux {
    /// timestamp → the submitter's channel.
    pending: Mutex<HashMap<i64, mpsc::Sender<MpResult<Packet>>>>,
    /// Tickets resolved so far (Ok results and flushed errors alike).
    resolved: AtomicU64,
    /// Optional wake-up hook ([`StreamingSession::set_result_notifier`]).
    notify: Mutex<Option<ResultNotifier>>,
}

impl Demux {
    /// Resolve the ticket registered at `ts` (at most once — the entry
    /// is removed first, so a misbehaving graph emitting a timestamp
    /// twice cannot double-answer), then ping the notifier.
    fn deliver(&self, ts: i64, result: MpResult<Packet>) {
        let sender = {
            let mut pending = lock_recover(&self.pending);
            let sender = pending.remove(&ts);
            if sender.is_some() {
                // Count under the map lock (and before the send): a
                // removed ticket is *always* already counted, so an
                // empty map implies resolved == submitted, and a waiter
                // holding its result never reads a stale counter.
                self.resolved.fetch_add(1, Ordering::AcqRel);
            }
            sender
        };
        if let Some(tx) = sender {
            let _ = tx.send(result);
            self.ping();
        }
    }

    /// Fail every still-pending ticket with `err`, then ping once.
    fn fail_all(&self, err: &MpError) {
        let drained: Vec<_> = {
            let mut pending = lock_recover(&self.pending);
            let drained: Vec<_> = pending.drain().collect();
            self.resolved
                .fetch_add(drained.len() as u64, Ordering::AcqRel);
            drained
        };
        if drained.is_empty() {
            return;
        }
        for (_, tx) in drained {
            let _ = tx.send(Err(err.clone()));
        }
        self.ping();
    }

    fn ping(&self) {
        if let Some(n) = lock_recover(&self.notify).as_ref() {
            n();
        }
    }
}

/// What a finished session did (metrics evidence).
#[derive(Clone, Copy, Debug, Default)]
pub struct SessionStats {
    /// Requests (timestamps) submitted over the session's life.
    pub timestamps: u64,
    /// Tickets resolved over the session's life (equals `timestamps`
    /// after a finish/drop: unresolved tickets are flushed with errors).
    pub resolved: u64,
    /// Tracer events the session's graph recorded.
    pub trace_events: usize,
}

/// The receipt for one submitted timestamp: wait on it to get exactly
/// that timestamp's result packet.
pub struct SessionTicket {
    ts: Timestamp,
    rx: mpsc::Receiver<MpResult<Packet>>,
}

impl SessionTicket {
    /// The timestamp this request was scheduled at.
    pub fn timestamp(&self) -> Timestamp {
        self.ts
    }

    /// Non-blocking check: `Some` if this timestamp's result (or the
    /// session's flushed error) is already buffered, `None` otherwise.
    /// Owners pipelining many tickets use this with
    /// [`StreamingSession::set_result_notifier`] to resolve ready
    /// tickets without blocking on any single one.
    pub fn try_wait(&self) -> Option<MpResult<Packet>> {
        match self.rx.try_recv() {
            Ok(result) => Some(result),
            Err(mpsc::TryRecvError::Empty) => None,
            Err(mpsc::TryRecvError::Disconnected) => Some(Err(MpError::Runtime(
                "streaming session closed before delivering this timestamp's result".into(),
            ))),
        }
    }

    /// Deadline-form [`SessionTicket::wait`]: block until this
    /// timestamp's result arrives or `deadline` passes. The serving
    /// layer's overload control waits on absolute per-batch deadlines
    /// (`submitted_at + batch_timeout`), so the bound never drifts as
    /// the wait is retried.
    pub fn wait_until(&self, deadline: std::time::Instant) -> MpResult<Packet> {
        self.wait(deadline.saturating_duration_since(std::time::Instant::now()))
    }

    /// Block until this timestamp's result arrives (or the session
    /// dies / the timeout elapses). Channel-waited: no polling.
    pub fn wait(&self, timeout: Duration) -> MpResult<Packet> {
        match self.rx.recv_timeout(timeout) {
            Ok(result) => result,
            Err(mpsc::RecvTimeoutError::Timeout) => Err(MpError::Runtime(format!(
                "streaming session: no result for timestamp {} within {timeout:?}",
                self.ts.raw()
            ))),
            Err(mpsc::RecvTimeoutError::Disconnected) => Err(MpError::Runtime(
                "streaming session closed before delivering this timestamp's result".into(),
            )),
        }
    }
}

/// A long-lived graph instance serving successive requests as
/// successive timestamps (module docs). Shareable across producer
/// threads (`&self` submission API; `Send + Sync`).
pub struct StreamingSession {
    graph: Option<PooledGraph>,
    input: InputHandle,
    demux: Arc<Demux>,
    state: Mutex<SessionState>,
    max_timestamps: u64,
}

struct SessionState {
    /// The next auto-assigned timestamp; explicit timestamps below this
    /// watermark are duplicates/regressions and rejected.
    next_ts: i64,
    submitted: u64,
}

impl StreamingSession {
    /// Start a session on a pooled graph: register the per-timestamp
    /// demux on `output_stream`, start the run with `side` packets, and
    /// open an [`InputHandle`] on `input_stream`. `max_timestamps` is
    /// the recycle threshold ([`StreamingSession::needs_recycle`]); 0
    /// means never.
    pub fn start(
        mut graph: PooledGraph,
        input_stream: &str,
        output_stream: &str,
        side: SidePackets,
        max_timestamps: u64,
    ) -> MpResult<StreamingSession> {
        let demux = Arc::new(Demux {
            pending: Mutex::new(HashMap::new()),
            resolved: AtomicU64::new(0),
            notify: Mutex::new(None),
        });
        let router = Arc::clone(&demux);
        graph.observe_output(output_stream, move |pkt| {
            router.deliver(pkt.timestamp().raw(), Ok(pkt.clone()));
        })?;
        // A dying run fails every in-flight ticket *immediately* with
        // the run's own error — pipelined owners must not have to wait
        // out a timeout to learn their window is dead. (fail_all is
        // idempotent, as the notifier contract requires.)
        let death = Arc::clone(&demux);
        graph.set_fail_notifier(move |e| death.fail_all(e));
        graph.start_run(side)?;
        let input = graph.input_handle(input_stream)?;
        Ok(StreamingSession {
            graph: Some(graph),
            input,
            demux,
            state: Mutex::new(SessionState {
                next_ts: 0,
                submitted: 0,
            }),
            max_timestamps,
        })
    }

    /// Start a session that demultiplexes **several** output streams:
    /// each timestamp resolves once every listed stream has produced its
    /// packet for that timestamp, and the ticket receives one
    /// [`ServingPayload::Map`] packet keyed by stream name in the
    /// declared order — the serving layer's multi-output aggregation
    /// seam (a catalog graph like `pose_landmark` declares `pose` and
    /// `angles`; a request wants both, synchronized). A single-stream
    /// list degenerates to [`StreamingSession::start`], which delivers
    /// the raw output packet without wrapping.
    ///
    /// A stream that never fires for a submitted timestamp leaves that
    /// ticket pending; the owner's batch timeout (and the run-death
    /// flush) bound the wait exactly as for single-output sessions.
    pub fn start_multi(
        mut graph: PooledGraph,
        input_stream: &str,
        output_streams: &[String],
        side: SidePackets,
        max_timestamps: u64,
    ) -> MpResult<StreamingSession> {
        match output_streams {
            [] => {
                return Err(MpError::Validation(
                    "streaming session needs at least one output stream".into(),
                ))
            }
            [only] => {
                return StreamingSession::start(graph, input_stream, only, side, max_timestamps)
            }
            _ => {}
        }
        let demux = Arc::new(Demux {
            pending: Mutex::new(HashMap::new()),
            resolved: AtomicU64::new(0),
            notify: Mutex::new(None),
        });
        // Per-timestamp partial rows: one slot per output stream, in
        // declared order. An entry leaves the map exactly once — when
        // its last slot fills (delivered) or on run death (cleared).
        type PartialRows = Mutex<HashMap<i64, Vec<Option<Packet>>>>;
        let partials: Arc<PartialRows> = Arc::new(Mutex::new(HashMap::new()));
        let names: Arc<Vec<String>> = Arc::new(output_streams.to_vec());
        let slots = output_streams.len();
        for (idx, stream) in output_streams.iter().enumerate() {
            let router = Arc::clone(&demux);
            let rows = Arc::clone(&partials);
            let names = Arc::clone(&names);
            graph.observe_output(stream, move |pkt| {
                let ts = pkt.timestamp().raw();
                let complete = {
                    let mut rows = lock_recover(&rows);
                    let row = rows.entry(ts).or_insert_with(|| vec![None; slots]);
                    row[idx] = Some(pkt.clone());
                    if row.iter().all(Option::is_some) {
                        rows.remove(&ts)
                    } else {
                        None
                    }
                };
                let Some(row) = complete else { return };
                let mut entries = Vec::with_capacity(slots);
                let mut failure = None;
                for (name, slot) in names.iter().zip(row) {
                    let pkt = slot.expect("row complete");
                    match ServingPayload::from_packet(&pkt) {
                        Ok(p) => entries.push((name.clone(), p)),
                        Err(e) => {
                            failure = Some(e);
                            break;
                        }
                    }
                }
                let result = match failure {
                    None => Ok(Packet::new(
                        ServingPayload::Map(entries),
                        Timestamp::new(ts),
                    )),
                    Some(e) => Err(e),
                };
                router.deliver(ts, result);
            })?;
        }
        let death = Arc::clone(&demux);
        let dead_rows = Arc::clone(&partials);
        graph.set_fail_notifier(move |e| {
            // Orphaned partial rows can never complete once the run is
            // dead; drop them before flushing their tickets.
            lock_recover(&dead_rows).clear();
            death.fail_all(e);
        });
        graph.start_run(side)?;
        let input = graph.input_handle(input_stream)?;
        Ok(StreamingSession {
            graph: Some(graph),
            input,
            demux,
            state: Mutex::new(SessionState {
                next_ts: 0,
                submitted: 0,
            }),
            max_timestamps,
        })
    }

    /// Register a wake-up hook called every time a ticket becomes
    /// resolvable (a result was routed, or pending tickets were flushed
    /// with errors). An owner pipelining K tickets sleeps on whatever
    /// primitive the hook pokes instead of polling K channels. The hook
    /// runs on graph executor threads: it must not block.
    pub fn set_result_notifier(&self, f: impl Fn() + Send + Sync + 'static) {
        *lock_recover(&self.demux.notify) = Some(Box::new(f));
    }

    /// The config version the session's graph was built from, pinned
    /// for the session's lifetime. The serving layer compares this with
    /// the pool's current version to drain sessions blue-green after a
    /// [`crate::serving::GraphRegistry::swap`].
    pub fn version(&self) -> std::sync::Arc<crate::serving::GraphVersion> {
        std::sync::Arc::clone(
            self.graph
                .as_ref()
                .expect("graph present until finish/drop")
                .version(),
        )
    }

    /// A producer handle for *another* graph input stream (beyond the
    /// session's own), for multi-input graphs — e.g. a control stream
    /// gating the session's data stream in tests.
    pub fn input_handle(&self, stream: &str) -> MpResult<InputHandle> {
        self.graph
            .as_ref()
            .expect("graph present until finish/drop")
            .input_handle(stream)
    }

    /// Submit a request at the next free timestamp. The payload packet's
    /// own timestamp is ignored; it is re-stamped with the assigned one.
    pub fn submit(&self, payload: Packet) -> MpResult<SessionTicket> {
        let mut st = lock_recover(&self.state);
        let ts = Timestamp::new(st.next_ts);
        self.submit_locked(&mut st, ts, payload)
    }

    /// Submit a request at an explicit timestamp. The timestamp must be
    /// strictly beyond every previously submitted one: duplicates and
    /// out-of-order submissions are rejected with a clean
    /// [`MpError::TimestampViolation`] (the session stays usable).
    pub fn submit_at(&self, ts: Timestamp, payload: Packet) -> MpResult<SessionTicket> {
        let mut st = lock_recover(&self.state);
        if !ts.is_normal() || ts.raw() < st.next_ts {
            return Err(MpError::TimestampViolation {
                stream: self.input.stream().to_string(),
                packet_ts: ts.raw(),
                bound: st.next_ts,
            });
        }
        self.submit_locked(&mut st, ts, payload)
    }

    fn submit_locked(
        &self,
        st: &mut SessionState,
        ts: Timestamp,
        payload: Packet,
    ) -> MpResult<SessionTicket> {
        if self.input.is_cancelled() {
            return Err(MpError::Runtime(
                "streaming session: graph run has stopped; recycle the session".into(),
            ));
        }
        let (tx, rx) = mpsc::channel();
        lock_recover(&self.demux.pending).insert(ts.raw(), tx);
        // Push-and-settle while holding the session lock: pushes enter
        // the stream strictly monotonically even under concurrent
        // submitters. The demux entry is registered first, so a result
        // can never arrive before its ticket exists.
        if let Err(e) = self.input.push_final(payload.at(ts)) {
            let removed = lock_recover(&self.demux.pending).remove(&ts.raw()).is_some();
            if !removed {
                // A concurrent run-death flush already failed (and
                // counted) this ticket, but the submission itself is
                // being rejected: take the phantom resolution back so
                // resolved never exceeds submitted.
                self.demux.resolved.fetch_sub(1, Ordering::AcqRel);
            }
            return Err(e);
        }
        st.next_ts = ts.raw() + 1;
        st.submitted += 1;
        Ok(SessionTicket { ts, rx })
    }

    /// Requests submitted so far.
    pub fn timestamps_submitted(&self) -> u64 {
        lock_recover(&self.state).submitted
    }

    /// Tickets resolved so far (results routed plus errors flushed).
    /// The recycle *trigger* is submission-based ([`StreamingSession::needs_recycle`]);
    /// owners drain until `timestamps_resolved == timestamps_submitted`
    /// before actually retiring, so no ticket is abandoned by a planned
    /// recycle.
    pub fn timestamps_resolved(&self) -> u64 {
        self.demux.resolved.load(Ordering::Acquire)
    }

    /// Tickets still waiting for their timestamp's result.
    pub fn pending_count(&self) -> usize {
        lock_recover(&self.demux.pending).len()
    }

    /// Fail every still-pending ticket with `err` without ending the
    /// session. Owners use this when they must answer waiters *now*
    /// (shutdown deadlines) while the graph drains separately; tickets
    /// submitted afterwards are unaffected.
    pub fn fail_pending(&self, err: &MpError) {
        self.demux.fail_all(err);
    }

    /// The recycle threshold this session was started with.
    pub fn max_timestamps(&self) -> u64 {
        self.max_timestamps
    }

    /// Has the session *submitted* its recycle threshold's worth of
    /// timestamps? The owner should stop feeding it and, once the
    /// in-flight tickets resolve, retire it as a planned recycle.
    pub fn at_submission_threshold(&self) -> bool {
        self.max_timestamps > 0 && lock_recover(&self.state).submitted >= self.max_timestamps
    }

    /// Should the owner recycle this session (threshold reached or the
    /// graph run stopped underneath it)?
    pub fn needs_recycle(&self) -> bool {
        self.input.is_cancelled() || self.at_submission_threshold()
    }

    /// Abort the session's graph run. Pending work is abandoned (their
    /// tickets fail when the session is finished or dropped). Owners
    /// retiring a session because it *misbehaved* — timed out, returned
    /// malformed results — should cancel before [`StreamingSession::finish`]:
    /// finish alone waits for the run to drain, which a stuck graph
    /// never does.
    pub fn cancel(&self) {
        if let Some(graph) = self.graph.as_ref() {
            graph.cancel();
        }
    }

    /// Gracefully end the session: close the input stream, wait for the
    /// graph to drain, flush any still-pending tickets with an error,
    /// and check the used graph back into its pool (replacement build).
    /// Returns the graph run's result plus session stats (the stats are
    /// valid either way — a failed run still leaves tracer evidence).
    pub fn finish(mut self) -> (MpResult<()>, SessionStats) {
        let mut graph = self.graph.take().expect("graph present until finish/drop");
        let _ = self.input.close();
        // Multi-input graphs (control/gate streams) would otherwise
        // never drain; closing an already-closed input is a no-op.
        let _ = graph.close_all_inputs();
        let result = graph.wait_until_done();
        // Flush after the run fully stopped: no demux callback can race
        // this drain, so every ticket resolves exactly once.
        Self::flush_pending(&self.demux, &result);
        let stats = SessionStats {
            timestamps: lock_recover(&self.state).submitted,
            resolved: self.demux.resolved.load(Ordering::Acquire),
            trace_events: graph.tracer().snapshot().len(),
        };
        drop(graph);
        (result, stats)
    }

    fn flush_pending(demux: &Demux, result: &MpResult<()>) {
        let err = match result {
            Ok(()) => MpError::Runtime(
                "streaming session ended before delivering this timestamp's result".into(),
            ),
            Err(e) => e.clone(),
        };
        demux.fail_all(&err);
    }
}

impl Drop for StreamingSession {
    fn drop(&mut self) {
        // A session dropped mid-batch (owner error path, test teardown,
        // server shutdown) must neither hang nor strand waiters: cancel
        // the run, join it (queue shutdown waits only for in-flight
        // tasks), then fail every pending ticket.
        let Some(mut graph) = self.graph.take() else {
            return;
        };
        graph.cancel();
        let result = graph.wait_until_done();
        Self::flush_pending(&self.demux, &result);
        drop(graph); // used check-in: the pool replaces it
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn session_is_send_and_sync() {
        fn assert_send_sync<T: Send + Sync>() {}
        assert_send_sync::<StreamingSession>();
        fn assert_send<T: Send>() {}
        assert_send::<SessionTicket>();
    }
}
