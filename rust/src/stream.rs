//! Streams: timestamped packet queues between nodes (§3.2).
//!
//! An output stream can be connected to any number of input streams of
//! the same type; **each input stream receives its own copy of the
//! packets and maintains its own queue**, so the receiving node consumes
//! at its own pace. Packets on a stream must have monotonically
//! increasing timestamps, and every stream carries a timestamp bound
//! (§4.1.2).

use std::collections::VecDeque;

use crate::error::{MpError, MpResult};
use crate::packet::Packet;
use crate::timestamp::{Timestamp, TimestampBound};

/// The per-consumer receive queue of one input stream (§3.2: "maintains
/// its own queue to allow the receiving node to consume the packets at
/// its own pace").
#[derive(Debug)]
pub struct InputStreamQueue {
    /// Stream name (diagnostics / tracer).
    pub name: String,
    queue: VecDeque<(Packet, u64)>,
    bound: TimestampBound,
    /// Monotonic count of packets ever enqueued (tracer/metrics).
    total_added: u64,
    /// High-water mark of the queue length (visualizer, flow control).
    max_depth: usize,
}

impl InputStreamQueue {
    pub fn new(name: impl Into<String>) -> InputStreamQueue {
        InputStreamQueue {
            name: name.into(),
            queue: VecDeque::new(),
            bound: TimestampBound::UNSTARTED,
            total_added: 0,
            max_depth: 0,
        }
    }

    /// Enqueue a packet, enforcing the per-stream monotonicity invariant
    /// (§4.1.2). On success the bound advances to `ts + 1`.
    /// Uses a queue-local arrival sequence; the graph runner uses
    /// [`InputStreamQueue::push_seq`] with a node-wide counter so the
    /// Immediate policy can order arrivals *across* streams.
    pub fn push(&mut self, packet: Packet) -> MpResult<()> {
        let seq = self.total_added;
        self.push_seq(packet, seq)
    }

    /// Enqueue with an explicit arrival sequence number (shared across
    /// all queues of one node).
    pub fn push_seq(&mut self, packet: Packet, seq: u64) -> MpResult<()> {
        let ts = packet.timestamp();
        if !ts.is_allowed_in_stream() {
            return Err(MpError::TimestampViolation {
                stream: self.name.clone(),
                packet_ts: ts.raw(),
                bound: self.bound.0.raw(),
            });
        }
        if self.bound.is_settled(ts) || self.bound.is_done() {
            return Err(MpError::TimestampViolation {
                stream: self.name.clone(),
                packet_ts: ts.raw(),
                bound: self.bound.0.raw(),
            });
        }
        self.bound = TimestampBound::after_packet(ts);
        self.queue.push_back((packet, seq));
        self.total_added += 1;
        self.max_depth = self.max_depth.max(self.queue.len());
        Ok(())
    }

    /// Advance the bound without a packet (explicit bound propagation,
    /// footnote 6). Backwards moves are ignored (monotonic).
    pub fn advance_bound(&mut self, bound: TimestampBound) -> bool {
        self.bound.advance_to(bound)
    }

    /// Close the stream: bound becomes Done.
    pub fn close(&mut self) {
        self.bound = TimestampBound::DONE;
    }

    /// Current timestamp bound.
    pub fn bound(&self) -> TimestampBound {
        self.bound
    }

    /// Stream is closed and nothing is left to consume.
    pub fn is_exhausted(&self) -> bool {
        self.bound.is_done() && self.queue.is_empty()
    }

    /// Timestamp of the front (oldest unconsumed) packet.
    pub fn front_timestamp(&self) -> Option<Timestamp> {
        self.queue.front().map(|(p, _)| p.timestamp())
    }

    /// Arrival sequence of the front packet (Immediate-policy ordering).
    pub fn front_seq(&self) -> Option<u64> {
        self.queue.front().map(|(_, s)| *s)
    }

    /// The **settled frontier** of this stream for the default input
    /// policy: if a packet is queued, its timestamp (a settled timestamp
    /// carrying data); otherwise the bound tells how far emptiness is
    /// certain.
    pub fn frontier(&self) -> Frontier {
        match self.queue.front() {
            Some((p, _)) => Frontier::Packet(p.timestamp()),
            None => Frontier::EmptyUntil(self.bound),
        }
    }

    /// Pop the front packet iff its timestamp equals `ts`.
    pub fn pop_at(&mut self, ts: Timestamp) -> Option<Packet> {
        if self.queue.front().map(|(p, _)| p.timestamp()) == Some(ts) {
            self.queue.pop_front().map(|(p, _)| p)
        } else {
            None
        }
    }

    /// Pop the front packet unconditionally (Immediate policy).
    pub fn pop_front(&mut self) -> Option<Packet> {
        self.queue.pop_front().map(|(p, _)| p)
    }

    /// Drop all queued packets with timestamp < `ts` (used by real-time
    /// load-shedding policies). Returns how many were dropped.
    pub fn discard_before(&mut self, ts: Timestamp) -> usize {
        let mut dropped = 0;
        while let Some((front, _)) = self.queue.front() {
            if front.timestamp() < ts {
                self.queue.pop_front();
                dropped += 1;
            } else {
                break;
            }
        }
        dropped
    }

    /// Number of packets currently queued (flow control input, §4.1.4).
    pub fn len(&self) -> usize {
        self.queue.len()
    }

    pub fn is_empty(&self) -> bool {
        self.queue.is_empty()
    }

    /// Lifetime count of packets enqueued.
    pub fn total_added(&self) -> u64 {
        self.total_added
    }

    /// High-water mark of queue depth.
    pub fn max_depth(&self) -> usize {
        self.max_depth
    }
}

/// Where a stream's knowledge currently ends, from the consumer's view.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Frontier {
    /// A packet with this timestamp is queued (settled, has data).
    Packet(Timestamp),
    /// No packet queued; all timestamps `< bound` are settled-empty.
    EmptyUntil(TimestampBound),
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pkt(ts: i64) -> Packet {
        Packet::new(ts, Timestamp::new(ts))
    }

    #[test]
    fn push_advances_bound() {
        let mut q = InputStreamQueue::new("s");
        assert_eq!(q.bound(), TimestampBound::UNSTARTED);
        q.push(pkt(10)).unwrap();
        assert_eq!(q.bound(), TimestampBound(Timestamp::new(11)));
        assert_eq!(q.len(), 1);
    }

    #[test]
    fn monotonicity_enforced() {
        let mut q = InputStreamQueue::new("s");
        q.push(pkt(10)).unwrap();
        // equal timestamp: bound is 11, 10 is settled -> rejected
        let err = q.push(pkt(10)).unwrap_err();
        assert!(matches!(err, MpError::TimestampViolation { .. }));
        // going backwards: rejected
        assert!(q.push(pkt(5)).is_err());
        // strictly forward: fine
        q.push(pkt(11)).unwrap();
    }

    #[test]
    fn rejects_after_close() {
        let mut q = InputStreamQueue::new("s");
        q.close();
        assert!(q.push(pkt(1)).is_err());
        assert!(q.is_exhausted());
    }

    #[test]
    fn prestream_then_series() {
        let mut q = InputStreamQueue::new("s");
        q.push(Packet::new(0u8, Timestamp::PRESTREAM)).unwrap();
        assert_eq!(q.bound(), TimestampBound(Timestamp::MIN));
        q.push(pkt(0)).unwrap();
        // a second PreStream packet is illegal
        let mut q2 = InputStreamQueue::new("s2");
        q2.push(Packet::new(0u8, Timestamp::PRESTREAM)).unwrap();
        assert!(q2.push(Packet::new(1u8, Timestamp::PRESTREAM)).is_err());
    }

    #[test]
    fn poststream_closes() {
        let mut q = InputStreamQueue::new("s");
        q.push(Packet::new(0u8, Timestamp::POSTSTREAM)).unwrap();
        assert!(q.bound().is_done());
        assert!(!q.is_exhausted()); // packet still queued
        q.pop_front();
        assert!(q.is_exhausted());
    }

    #[test]
    fn explicit_bound_is_monotonic() {
        let mut q = InputStreamQueue::new("s");
        assert!(q.advance_bound(TimestampBound(Timestamp::new(50))));
        assert!(!q.advance_bound(TimestampBound(Timestamp::new(20))));
        // a packet beyond the bound is fine; before it is not
        assert!(q.push(pkt(20)).is_err());
        q.push(pkt(50)).unwrap();
    }

    #[test]
    fn frontier_reports_packet_or_bound() {
        let mut q = InputStreamQueue::new("s");
        assert_eq!(
            q.frontier(),
            Frontier::EmptyUntil(TimestampBound::UNSTARTED)
        );
        q.push(pkt(10)).unwrap();
        assert_eq!(q.frontier(), Frontier::Packet(Timestamp::new(10)));
        q.pop_at(Timestamp::new(10)).unwrap();
        assert_eq!(
            q.frontier(),
            Frontier::EmptyUntil(TimestampBound(Timestamp::new(11)))
        );
    }

    #[test]
    fn pop_at_only_matches_front() {
        let mut q = InputStreamQueue::new("s");
        q.push(pkt(10)).unwrap();
        q.push(pkt(20)).unwrap();
        assert!(q.pop_at(Timestamp::new(20)).is_none());
        assert!(q.pop_at(Timestamp::new(10)).is_some());
        assert!(q.pop_at(Timestamp::new(20)).is_some());
        assert!(q.is_empty());
    }

    #[test]
    fn discard_before_drops_stale() {
        let mut q = InputStreamQueue::new("s");
        for t in [10, 20, 30] {
            q.push(pkt(t)).unwrap();
        }
        assert_eq!(q.discard_before(Timestamp::new(25)), 2);
        assert_eq!(q.front_timestamp(), Some(Timestamp::new(30)));
    }

    #[test]
    fn stats_track_depth_and_total() {
        let mut q = InputStreamQueue::new("s");
        for t in [1, 2, 3] {
            q.push(pkt(t)).unwrap();
        }
        q.pop_front();
        q.push(pkt(4)).unwrap();
        assert_eq!(q.total_added(), 4);
        assert_eq!(q.max_depth(), 3);
    }
}
