//! Minimal benchmarking support for the `cargo bench` harnesses (the
//! vendored offline environment has no criterion; these benches print
//! the same kind of table the paper's evaluation would), plus the stub
//! artifact dir serving benches and integration tests share.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::time::{Duration, Instant};

/// Write a stub detector manifest (batch variants 1 and 4, 8x8 input)
/// into a unique temp dir and return its path. The runtime's reference
/// backend needs only this manifest — no compiled HLO files — so the
/// serving benches and integration tests can run fully offline.
/// `prefix` keeps concurrent users (test binaries, benches) apart.
pub fn stub_detector_artifacts(prefix: &str) -> String {
    static NEXT: AtomicUsize = AtomicUsize::new(0);
    let dir = std::env::temp_dir().join(format!(
        "{prefix}-{}-{}",
        std::process::id(),
        NEXT.fetch_add(1, Ordering::Relaxed)
    ));
    std::fs::create_dir_all(&dir).expect("create stub artifact dir");
    std::fs::write(
        dir.join("manifest.txt"),
        "# mp-artifacts v1\n\
         model detector detector.hlo.txt\n\
         input image f32 1,8,8,1\n\
         output boxes f32 16,4\n\
         output scores f32 16\n\
         endmodel\n\
         model detector_b4 detector_b4.hlo.txt\n\
         input image f32 4,8,8,1\n\
         output boxes f32 64,4\n\
         output scores f32 64\n\
         endmodel\n",
    )
    .expect("write stub manifest");
    dir.to_string_lossy().into_owned()
}

/// Park one worker of `pool` behind a gate: submits a task that signals
/// entry and then blocks until the returned sender fires (or drops, so
/// a panicking test releases the worker instead of hanging the pool).
/// Returns only once the worker is provably inside the gate — the
/// deterministic scheduling-test scaffold: park the only worker, stage
/// queues/sources, then release and observe the dispatch order. Shared
/// by the executor/scheduler tests and the scan-scale bench.
pub fn park_worker(pool: &crate::executor::ThreadPoolExecutor) -> std::sync::mpsc::Sender<()> {
    use crate::executor::Executor;
    let (gate_tx, gate_rx) = std::sync::mpsc::channel::<()>();
    let (entered_tx, entered_rx) = std::sync::mpsc::channel::<()>();
    pool.execute(Box::new(move || {
        entered_tx.send(()).unwrap();
        let _ = gate_rx.recv();
    }));
    entered_rx.recv().unwrap();
    gate_tx
}

/// [`park_worker`] for every worker of the pool: returns one gate per
/// worker, all provably entered. Deterministic — a gated worker cannot
/// take the next gate task, so each submission lands on a distinct
/// worker. The worker-sweep bench stages all queues behind this, then
/// releases every gate at once to measure a full-pool dispatch race.
pub fn park_all_workers(
    pool: &crate::executor::ThreadPoolExecutor,
) -> Vec<std::sync::mpsc::Sender<()>> {
    use crate::executor::Executor;
    (0..pool.num_threads()).map(|_| park_worker(pool)).collect()
}

/// Iteration count for race-hammering tests: the `STRESS_ITERS` env var
/// (set by CI's release-mode stress step) overrides the in-tree
/// default, so the same tests serve as quick regression checks locally
/// and as a soak under load in CI.
pub fn stress_iters(default: usize) -> usize {
    std::env::var("STRESS_ITERS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

/// Fire `n` synthetic frames at a serving handle **without waiting
/// between submissions** (the async wave that lets a pipelined batcher
/// keep its window full), then wait for every reply. Returns the wall
/// time from first submission to last reply plus the number of error /
/// missing replies. Shared by the serving benches and pipelining tests.
pub fn detect_wave(
    handle: &crate::serving::ServerHandle,
    world: &mut crate::perception::SyntheticWorld,
    n: usize,
) -> (Duration, usize) {
    let t0 = Instant::now();
    let mut replies = Vec::with_capacity(n);
    for _ in 0..n {
        world.step();
        replies.push(handle.submit(&world.render()));
    }
    let mut errors = 0usize;
    for rx in replies {
        match rx.recv_timeout(Duration::from_secs(120)) {
            Ok(Ok(_dets)) => {}
            _ => errors += 1,
        }
    }
    (t0.elapsed(), errors)
}

/// Timed samples with summary statistics.
pub struct Samples {
    pub name: String,
    samples: Vec<Duration>,
}

impl Samples {
    pub fn new(name: &str) -> Samples {
        Samples {
            name: name.to_string(),
            samples: Vec::new(),
        }
    }

    pub fn add(&mut self, d: Duration) {
        self.samples.push(d);
    }

    /// Run `f` for `warmup + iters` iterations, timing the last `iters`.
    pub fn run(name: &str, warmup: usize, iters: usize, mut f: impl FnMut()) -> Samples {
        for _ in 0..warmup {
            f();
        }
        let mut s = Samples::new(name);
        for _ in 0..iters {
            let t0 = Instant::now();
            f();
            s.add(t0.elapsed());
        }
        s
    }

    pub fn mean(&self) -> Duration {
        if self.samples.is_empty() {
            return Duration::ZERO;
        }
        self.samples.iter().sum::<Duration>() / self.samples.len() as u32
    }

    pub fn quantile(&self, q: f64) -> Duration {
        if self.samples.is_empty() {
            return Duration::ZERO;
        }
        let mut v = self.samples.clone();
        v.sort_unstable();
        v[(((v.len() - 1) as f64) * q).round() as usize]
    }

    pub fn min(&self) -> Duration {
        self.samples.iter().min().copied().unwrap_or(Duration::ZERO)
    }

    /// One formatted row: name, mean, p50, p95, min.
    pub fn row(&self) -> String {
        format!(
            "{:<44} mean {:>10.2?}  p50 {:>10.2?}  p95 {:>10.2?}  min {:>10.2?}",
            self.name,
            self.mean(),
            self.quantile(0.5),
            self.quantile(0.95),
            self.min()
        )
    }
}

/// Print a bench section header.
pub fn section(title: &str) {
    println!("\n=== {title} ===");
}

/// items/second from a count and a duration.
pub fn per_sec(count: usize, d: Duration) -> f64 {
    count as f64 / d.as_secs_f64()
}

/// Render a simple aligned table.
pub fn table(headers: &[&str], rows: &[Vec<String>]) {
    let mut widths: Vec<usize> = headers.iter().map(|h| h.len()).collect();
    for r in rows {
        for (i, c) in r.iter().enumerate() {
            if i < widths.len() {
                widths[i] = widths[i].max(c.len());
            }
        }
    }
    let fmt_row = |cells: Vec<String>| {
        cells
            .iter()
            .enumerate()
            .map(|(i, c)| format!("{:>w$}", c, w = widths.get(i).copied().unwrap_or(8)))
            .collect::<Vec<_>>()
            .join("  ")
    };
    println!(
        "{}",
        fmt_row(headers.iter().map(|s| s.to_string()).collect())
    );
    for r in rows {
        println!("{}", fmt_row(r.clone()));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn samples_stats() {
        let mut s = Samples::new("x");
        for ms in [1u64, 2, 3, 4, 100] {
            s.add(Duration::from_millis(ms));
        }
        assert_eq!(s.quantile(0.5), Duration::from_millis(3));
        assert_eq!(s.min(), Duration::from_millis(1));
        assert!(s.mean() >= Duration::from_millis(20));
        assert!(!s.row().is_empty());
    }

    #[test]
    fn run_times_closures() {
        let s = Samples::run("noop", 2, 5, || {
            std::hint::black_box(42);
        });
        assert_eq!(s.samples.len(), 5);
    }

    #[test]
    fn per_sec_math() {
        assert!((per_sec(100, Duration::from_secs(2)) - 50.0).abs() < 1e-9);
    }
}
