//! Offline trace analysis (§5.1): "histograms of various resources,
//! such as the elapsed CPU time across each calculator and across each
//! stream", aggregated latencies, and critical-path extraction.

use std::collections::HashMap;

use crate::tracer::export::TraceFile;
use crate::tracer::EventType;

/// Latency/duration statistics over a set of samples (µs).
#[derive(Clone, Debug, Default)]
pub struct Histogram {
    samples: Vec<u64>,
    sorted: bool,
}

impl Histogram {
    pub fn add(&mut self, v: u64) {
        self.samples.push(v);
        self.sorted = false;
    }

    pub fn count(&self) -> usize {
        self.samples.len()
    }

    pub fn sum(&self) -> u64 {
        self.samples.iter().sum()
    }

    pub fn mean(&self) -> f64 {
        if self.samples.is_empty() {
            0.0
        } else {
            self.sum() as f64 / self.samples.len() as f64
        }
    }

    pub fn min(&self) -> u64 {
        self.samples.iter().copied().min().unwrap_or(0)
    }

    pub fn max(&self) -> u64 {
        self.samples.iter().copied().max().unwrap_or(0)
    }

    fn ensure_sorted(&mut self) {
        if !self.sorted {
            self.samples.sort_unstable();
            self.sorted = true;
        }
    }

    /// q in [0, 1]; nearest-rank.
    pub fn quantile(&mut self, q: f64) -> u64 {
        if self.samples.is_empty() {
            return 0;
        }
        self.ensure_sorted();
        let idx = ((self.samples.len() as f64 - 1.0) * q).round() as usize;
        self.samples[idx.min(self.samples.len() - 1)]
    }

    pub fn p50(&mut self) -> u64 {
        self.quantile(0.50)
    }

    pub fn p95(&mut self) -> u64 {
        self.quantile(0.95)
    }

    pub fn p99(&mut self) -> u64 {
        self.quantile(0.99)
    }

    /// Log-bucketed counts (1-2-5 decades), for the text visualizer.
    pub fn buckets(&self) -> Vec<(u64, usize)> {
        const EDGES: [u64; 15] = [
            1, 2, 5, 10, 20, 50, 100, 200, 500, 1_000, 2_000, 5_000, 10_000, 100_000, 1_000_000,
        ];
        let mut counts = vec![0usize; EDGES.len() + 1];
        for &s in &self.samples {
            let i = EDGES.iter().position(|&e| s < e).unwrap_or(EDGES.len());
            counts[i] += 1;
        }
        let mut out = Vec::new();
        for (i, &c) in counts.iter().enumerate() {
            if c > 0 {
                let edge = if i < EDGES.len() { EDGES[i] } else { u64::MAX };
                out.push((edge, c));
            }
        }
        out
    }
}

/// Per-node aggregate extracted from a trace.
#[derive(Clone, Debug, Default)]
pub struct NodeProfile {
    pub name: String,
    /// Process() wall durations.
    pub process: Histogram,
    pub invocations: usize,
    /// Total µs inside Process (the "elapsed CPU time across each
    /// calculator" histogram input).
    pub total_us: u64,
}

/// Per-stream aggregate.
#[derive(Clone, Debug, Default)]
pub struct StreamProfile {
    pub name: String,
    pub packets: usize,
    /// µs between PacketEmitted and the matched PacketAdded (transport +
    /// queueing is ~0 in-process; dominated by queue wait downstream).
    pub queue_wait: Histogram,
}

/// End-to-end per-packet-timestamp path statistics.
#[derive(Clone, Debug, Default)]
pub struct PathStats {
    /// GraphInput (or first emit) -> last GraphOutput latency.
    pub e2e_latency: Histogram,
    /// Node name -> total µs attributed on the critical path.
    pub critical_us: HashMap<String, u64>,
}

/// Full analysis result.
#[derive(Clone, Debug, Default)]
pub struct Profile {
    pub nodes: Vec<NodeProfile>,
    pub streams: Vec<StreamProfile>,
    pub paths: PathStats,
    pub dropped_events: u64,
    pub span_us: u64,
}

/// Aggregate a trace (§5.1: "timing data can be aggregated to report
/// average and extreme latencies ... and to identify the calculators
/// along the critical path, whose performance determines end-to-end
/// latency").
pub fn analyze(trace: &TraceFile) -> Profile {
    let mut prof = Profile {
        nodes: trace
            .node_names
            .iter()
            .map(|n| NodeProfile {
                name: n.clone(),
                ..Default::default()
            })
            .collect(),
        streams: trace
            .stream_names
            .iter()
            .map(|n| StreamProfile {
                name: n.clone(),
                ..Default::default()
            })
            .collect(),
        ..Default::default()
    };

    // Node process durations: match Start/End per (node, thread).
    let mut open_start: HashMap<(u32, u32), u64> = HashMap::new();
    // E2E: first GraphInput time and last GraphOutput time per packet_ts.
    let mut first_in: HashMap<i64, u64> = HashMap::new();
    let mut last_out: HashMap<i64, u64> = HashMap::new();
    // Per-packet_ts processing spans for the critical path.
    let mut spans: HashMap<i64, Vec<(u32, u64, u64)>> = HashMap::new(); // ts -> (node, start, end)
    let mut span_start: HashMap<(u32, u32), (i64, u64)> = HashMap::new();
    // Stream queue wait: PacketEmitted(data_id) -> GraphOutput/Added.
    let mut emitted_at: HashMap<u64, u64> = HashMap::new();

    let (mut tmin, mut tmax) = (u64::MAX, 0u64);
    for e in &trace.events {
        tmin = tmin.min(e.event_time_us);
        tmax = tmax.max(e.event_time_us);
        match e.event_type {
            EventType::ProcessStart => {
                open_start.insert((e.node_id, e.thread_id), e.event_time_us);
                span_start.insert((e.node_id, e.thread_id), (e.packet_ts, e.event_time_us));
            }
            EventType::ProcessEnd => {
                if let Some(s) = open_start.remove(&(e.node_id, e.thread_id)) {
                    let d = e.event_time_us.saturating_sub(s);
                    if let Some(np) = prof.nodes.get_mut(e.node_id as usize) {
                        np.process.add(d);
                        np.invocations += 1;
                        np.total_us += d;
                    }
                }
                if let Some((ts, s)) = span_start.remove(&(e.node_id, e.thread_id)) {
                    spans
                        .entry(ts)
                        .or_default()
                        .push((e.node_id, s, e.event_time_us));
                }
            }
            EventType::PacketEmitted => {
                emitted_at.insert(e.packet_data_id, e.event_time_us);
                if let Some(sp) = prof.streams.get_mut(e.stream_id as usize) {
                    sp.packets += 1;
                }
            }
            EventType::PacketAdded => {
                if let Some(&em) = emitted_at.get(&e.packet_data_id) {
                    if let Some(sp) = prof.streams.get_mut(e.stream_id as usize) {
                        sp.queue_wait.add(e.event_time_us.saturating_sub(em));
                    }
                }
            }
            EventType::GraphInput => {
                first_in.entry(e.packet_ts).or_insert(e.event_time_us);
            }
            EventType::GraphOutput => {
                let slot = last_out.entry(e.packet_ts).or_insert(0);
                *slot = (*slot).max(e.event_time_us);
            }
            _ => {}
        }
    }
    if tmin != u64::MAX {
        prof.span_us = tmax - tmin;
    }

    // E2E latency per timestamp; attribute critical-path time to the
    // nodes whose Process spans overlapped that timestamp's lifetime.
    for (ts, &out_t) in &last_out {
        let in_t = first_in
            .get(ts)
            .copied()
            .or_else(|| spans.get(ts).and_then(|v| v.iter().map(|s| s.1).min()));
        if let Some(in_t) = in_t {
            if out_t >= in_t {
                prof.paths.e2e_latency.add(out_t - in_t);
            }
        }
        if let Some(nodespans) = spans.get(ts) {
            for (node, s, e) in nodespans {
                let name = trace.node_name(*node).to_string();
                *prof.paths.critical_us.entry(name).or_insert(0) += e.saturating_sub(*s);
            }
        }
    }
    prof
}

/// Render a human-readable report (the CLI `trace` subcommand output).
pub fn report(prof: &mut Profile) -> String {
    let mut out = String::new();
    out.push_str(&format!(
        "trace span: {:.3} ms\n\nper-calculator Process() time (µs):\n",
        prof.span_us as f64 / 1000.0
    ));
    out.push_str(&format!(
        "{:<32} {:>8} {:>10} {:>8} {:>8} {:>8} {:>8}\n",
        "calculator", "calls", "total", "mean", "p50", "p95", "max"
    ));
    let mut idx: Vec<usize> = (0..prof.nodes.len()).collect();
    idx.sort_by_key(|&i| std::cmp::Reverse(prof.nodes[i].total_us));
    for i in idx {
        let n = &mut prof.nodes[i];
        if n.invocations == 0 {
            continue;
        }
        let (mean, p50, p95, max) = (n.process.mean(), n.process.p50(), n.process.p95(), n.process.max());
        out.push_str(&format!(
            "{:<32} {:>8} {:>10} {:>8.1} {:>8} {:>8} {:>8}\n",
            n.name, n.invocations, n.total_us, mean, p50, p95, max
        ));
    }
    out.push_str("\nper-stream packets / queue-wait µs (p50/p95):\n");
    for s in &mut prof.streams {
        if s.packets == 0 {
            continue;
        }
        let (p50, p95) = (s.queue_wait.p50(), s.queue_wait.p95());
        out.push_str(&format!(
            "{:<32} {:>8} {:>8} {:>8}\n",
            s.name, s.packets, p50, p95
        ));
    }
    if prof.paths.e2e_latency.count() > 0 {
        let l = &mut prof.paths.e2e_latency;
        out.push_str(&format!(
            "\nend-to-end latency µs: n={} mean={:.1} p50={} p95={} p99={} max={}\n",
            l.count(),
            l.mean(),
            l.p50(),
            l.p95(),
            l.p99(),
            l.max()
        ));
        let mut crit: Vec<(&String, &u64)> = prof.paths.critical_us.iter().collect();
        crit.sort_by_key(|(_, &v)| std::cmp::Reverse(v));
        out.push_str("critical-path attribution (total µs while a timestamp was live):\n");
        for (name, us) in crit.iter().take(10) {
            out.push_str(&format!("  {:<30} {us}\n", name));
        }
    }
    if prof.dropped_events > 0 {
        out.push_str(&format!(
            "\nWARNING: {} events overwritten (grow profiler buffer_size)\n",
            prof.dropped_events
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::timestamp::Timestamp;
    use crate::tracer::{TraceEvent, Tracer};

    #[test]
    fn histogram_stats() {
        let mut h = Histogram::default();
        for v in [1u64, 2, 3, 4, 5, 6, 7, 8, 9, 10] {
            h.add(v);
        }
        assert_eq!(h.count(), 10);
        assert_eq!(h.sum(), 55);
        assert!((h.mean() - 5.5).abs() < 1e-9);
        assert_eq!(h.min(), 1);
        assert_eq!(h.max(), 10);
        assert_eq!(h.p50(), 6); // nearest-rank on 0-indexed
        assert_eq!(h.quantile(0.0), 1);
        assert_eq!(h.quantile(1.0), 10);
    }

    #[test]
    fn histogram_buckets() {
        let mut h = Histogram::default();
        for v in [0u64, 1, 3, 50, 5000] {
            h.add(v);
        }
        let b = h.buckets();
        let total: usize = b.iter().map(|(_, c)| c).sum();
        assert_eq!(total, 5);
    }

    #[test]
    fn analyze_process_durations_and_e2e() {
        let t = Tracer::new(256);
        t.set_names(vec!["a".into(), "b".into()], vec!["s0".into(), "s1".into()]);
        // simulate: input ts=10 at t=0; a processes 0..100; emits; b 100..250; output at 250
        let ts = Timestamp::new(10);
        let mk = |time, et, node, stream, data| TraceEvent {
            event_time_us: time,
            event_type: et,
            node_id: node,
            stream_id: stream,
            packet_ts: ts.raw(),
            packet_data_id: data,
            thread_id: 0,
        };
        let evs = vec![
            mk(0, EventType::GraphInput, TraceEvent::NO_NODE, 0, 1),
            mk(5, EventType::PacketAdded, 0, 0, 1),
            mk(10, EventType::ProcessStart, 0, TraceEvent::NO_STREAM, 0),
            mk(110, EventType::ProcessEnd, 0, TraceEvent::NO_STREAM, 0),
            mk(110, EventType::PacketEmitted, 0, 1, 2),
            mk(112, EventType::PacketAdded, 1, 1, 2),
            mk(120, EventType::ProcessStart, 1, TraceEvent::NO_STREAM, 0),
            mk(250, EventType::ProcessEnd, 1, TraceEvent::NO_STREAM, 0),
            mk(250, EventType::GraphOutput, TraceEvent::NO_NODE, 1, 3),
        ];
        let tf = TraceFile {
            node_names: t.node_names(),
            stream_names: t.stream_names(),
            events: evs,
        };
        let mut p = analyze(&tf);
        assert_eq!(p.nodes[0].invocations, 1);
        assert_eq!(p.nodes[0].total_us, 100);
        assert_eq!(p.nodes[1].total_us, 130);
        assert_eq!(p.paths.e2e_latency.count(), 1);
        assert_eq!(p.paths.e2e_latency.max(), 250);
        assert_eq!(p.paths.critical_us["b"], 130);
        // queue wait on stream 1: 112 - 110
        assert_eq!(p.streams[1].packets, 1);
        assert_eq!(p.streams[1].queue_wait.max(), 2);
        let rep = report(&mut p);
        assert!(rep.contains("end-to-end latency"));
        assert!(rep.contains('a'));
    }

    #[test]
    fn empty_trace_analyzes() {
        let tf = TraceFile::default();
        let mut p = analyze(&tf);
        assert_eq!(p.span_us, 0);
        let _ = report(&mut p);
    }
}
