//! Trace export/import (§5.1-5.2): traces are written to disk and fed
//! to the visualizer.
//!
//! Two formats:
//! * **mptrace TSV** — our native format, loss-free, loadable back by
//!   the visualizer (`load_tsv`).
//! * **Chrome trace JSON** — write-only, loadable in chrome://tracing
//!   or Perfetto for the Timeline view of Fig. 4.

use std::io::Write;

use crate::error::{MpError, MpResult};
use crate::tracer::{EventType, TraceEvent, Tracer};

/// A self-contained exported trace (events + name tables).
#[derive(Clone, Debug, Default)]
pub struct TraceFile {
    pub node_names: Vec<String>,
    pub stream_names: Vec<String>,
    pub events: Vec<TraceEvent>,
}

impl TraceFile {
    /// Capture the tracer's current contents.
    pub fn capture(tracer: &Tracer) -> TraceFile {
        TraceFile {
            node_names: tracer.node_names(),
            stream_names: tracer.stream_names(),
            events: tracer.snapshot(),
        }
    }

    pub fn node_name(&self, id: u32) -> &str {
        self.node_names
            .get(id as usize)
            .map(|s| s.as_str())
            .unwrap_or("<graph>")
    }

    pub fn stream_name(&self, id: u32) -> &str {
        self.stream_names
            .get(id as usize)
            .map(|s| s.as_str())
            .unwrap_or("<none>")
    }

    // -----------------------------------------------------------------
    // native TSV
    // -----------------------------------------------------------------

    /// Serialize to the native TSV format.
    pub fn to_tsv(&self) -> String {
        let mut out = String::new();
        out.push_str("#mptrace\tv1\n");
        for n in &self.node_names {
            out.push_str(&format!("#node\t{n}\n"));
        }
        for s in &self.stream_names {
            out.push_str(&format!("#stream\t{s}\n"));
        }
        out.push_str("#columns\ttime_us\tevent\tnode\tstream\tpacket_ts\tdata_id\tthread\n");
        for e in &self.events {
            out.push_str(&format!(
                "{}\t{}\t{}\t{}\t{}\t{}\t{}\n",
                e.event_time_us,
                e.event_type as u8,
                e.node_id,
                e.stream_id,
                e.packet_ts,
                e.packet_data_id,
                e.thread_id,
            ));
        }
        out
    }

    /// Parse the native TSV format.
    pub fn from_tsv(text: &str) -> MpResult<TraceFile> {
        let mut tf = TraceFile::default();
        for (lineno, line) in text.lines().enumerate() {
            let err = |msg: &str| MpError::Parse {
                line: lineno + 1,
                message: msg.to_string(),
            };
            if line.is_empty() {
                continue;
            }
            if let Some(rest) = line.strip_prefix('#') {
                let mut it = rest.split('\t');
                match it.next() {
                    Some("node") => tf
                        .node_names
                        .push(it.next().ok_or_else(|| err("missing node name"))?.to_string()),
                    Some("stream") => tf.stream_names.push(
                        it.next()
                            .ok_or_else(|| err("missing stream name"))?
                            .to_string(),
                    ),
                    _ => {} // header/columns comments
                }
                continue;
            }
            let cols: Vec<&str> = line.split('\t').collect();
            if cols.len() != 7 {
                return Err(err("expected 7 columns"));
            }
            let parse_u64 =
                |s: &str| s.parse::<u64>().map_err(|_| err("bad unsigned integer"));
            let ev = TraceEvent {
                event_time_us: parse_u64(cols[0])?,
                event_type: EventType::from_u8(
                    cols[1].parse::<u8>().map_err(|_| err("bad event type"))?,
                )
                .ok_or_else(|| err("unknown event type"))?,
                node_id: cols[2].parse::<u32>().map_err(|_| err("bad node id"))?,
                stream_id: cols[3].parse::<u32>().map_err(|_| err("bad stream id"))?,
                packet_ts: cols[4].parse::<i64>().map_err(|_| err("bad packet ts"))?,
                packet_data_id: parse_u64(cols[5])?,
                thread_id: cols[6].parse::<u32>().map_err(|_| err("bad thread id"))?,
            };
            tf.events.push(ev);
        }
        Ok(tf)
    }

    /// Write the native format to a file.
    pub fn save_tsv(&self, path: &str) -> MpResult<()> {
        let mut f = std::fs::File::create(path)?;
        f.write_all(self.to_tsv().as_bytes())?;
        Ok(())
    }

    /// Load the native format from a file.
    pub fn load_tsv(path: &str) -> MpResult<TraceFile> {
        let text = std::fs::read_to_string(path)?;
        TraceFile::from_tsv(&text)
    }

    // -----------------------------------------------------------------
    // Chrome trace JSON (write-only)
    // -----------------------------------------------------------------

    /// Serialize to the Chrome trace-event format (load in
    /// chrome://tracing or https://ui.perfetto.dev): ProcessStart/End
    /// become duration events on per-thread rows; packet events become
    /// instants.
    pub fn to_chrome_json(&self) -> String {
        fn esc(s: &str) -> String {
            s.replace('\\', "\\\\").replace('"', "\\\"")
        }
        let mut parts: Vec<String> = Vec::new();
        for e in &self.events {
            let name = match e.event_type {
                EventType::ProcessStart
                | EventType::ProcessEnd
                | EventType::OpenStart
                | EventType::OpenEnd
                | EventType::CloseStart
                | EventType::CloseEnd => esc(self.node_name(e.node_id)),
                _ => format!(
                    "{}:{}",
                    e.event_type.name(),
                    esc(self.stream_name(e.stream_id))
                ),
            };
            let ph = match e.event_type {
                EventType::ProcessStart | EventType::OpenStart | EventType::CloseStart => "B",
                EventType::ProcessEnd | EventType::OpenEnd | EventType::CloseEnd => "E",
                _ => "i",
            };
            let mut obj = format!(
                "{{\"name\":\"{name}\",\"ph\":\"{ph}\",\"ts\":{},\"pid\":1,\"tid\":{}",
                e.event_time_us, e.thread_id
            );
            if ph == "i" {
                obj.push_str(",\"s\":\"t\"");
            }
            obj.push_str(&format!(
                ",\"args\":{{\"packet_ts\":{},\"data_id\":{}}}}}",
                e.packet_ts, e.packet_data_id
            ));
            parts.push(obj);
        }
        format!("{{\"traceEvents\":[{}]}}", parts.join(","))
    }

    /// Write Chrome JSON to a file.
    pub fn save_chrome_json(&self, path: &str) -> MpResult<()> {
        let mut f = std::fs::File::create(path)?;
        f.write_all(self.to_chrome_json().as_bytes())?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::timestamp::Timestamp;

    fn sample() -> TraceFile {
        let t = Tracer::new(64);
        t.set_names(
            vec!["det".into(), "tracker".into()],
            vec!["frames".into(), "dets".into()],
        );
        t.record(EventType::ProcessStart, 0, TraceEvent::NO_STREAM, Timestamp::new(10), 0);
        t.record(EventType::PacketEmitted, 0, 1, Timestamp::new(10), 7);
        t.record(EventType::ProcessEnd, 0, TraceEvent::NO_STREAM, Timestamp::new(10), 0);
        TraceFile::capture(&t)
    }

    #[test]
    fn tsv_roundtrip() {
        let tf = sample();
        let text = tf.to_tsv();
        let tf2 = TraceFile::from_tsv(&text).unwrap();
        assert_eq!(tf.node_names, tf2.node_names);
        assert_eq!(tf.stream_names, tf2.stream_names);
        assert_eq!(tf.events, tf2.events);
    }

    #[test]
    fn tsv_rejects_garbage() {
        assert!(TraceFile::from_tsv("1\t2\t3\n").is_err());
        assert!(TraceFile::from_tsv("a\t99\t0\t0\t0\t0\t0\n").is_err());
    }

    #[test]
    fn chrome_json_is_wellformed_enough() {
        let tf = sample();
        let j = tf.to_chrome_json();
        assert!(j.starts_with("{\"traceEvents\":["));
        assert!(j.ends_with("]}"));
        assert!(j.contains("\"ph\":\"B\""));
        assert!(j.contains("\"ph\":\"E\""));
        assert!(j.contains("\"ph\":\"i\""));
        assert!(j.contains("det"));
        // balanced braces (cheap sanity check)
        let opens = j.matches('{').count();
        let closes = j.matches('}').count();
        assert_eq!(opens, closes);
    }

    #[test]
    fn file_roundtrip() {
        let tf = sample();
        let dir = std::env::temp_dir().join("mp_trace_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("t.tsv");
        tf.save_tsv(p.to_str().unwrap()).unwrap();
        let tf2 = TraceFile::load_tsv(p.to_str().unwrap()).unwrap();
        assert_eq!(tf.events.len(), tf2.events.len());
    }
}
