//! Mutex-free circular trace buffer (§5.1: "to avoid thread contention
//! ... the tracer module utilizes a mutex-free thread-safe buffer
//! implementation").
//!
//! Design: a fixed power-of-two slot array with a global atomic write
//! cursor. A writer claims a slot with one `fetch_add`, writes the
//! event, then publishes by storing `index + 1` into the slot's sequence
//! (seqlock-style). Readers (only at export time, when the graph is
//! quiescent or best-effort) validate the sequence around the read and
//! skip torn slots. Old events are overwritten when the ring wraps —
//! exactly the paper's circular-buffer semantics.

use std::cell::UnsafeCell;
use std::sync::atomic::{AtomicU64, Ordering};

use super::TraceEvent;

struct Slot {
    /// 0 = never written; otherwise (claim index + 1).
    seq: AtomicU64,
    event: UnsafeCell<TraceEvent>,
}

// SAFETY: concurrent access to `event` is coordinated through `seq`
// (write-then-publish; readers validate seq before/after the read and
// discard torn data).
unsafe impl Sync for Slot {}

pub struct TraceRing {
    slots: Box<[Slot]>,
    mask: u64,
    head: AtomicU64,
}

impl TraceRing {
    /// Ring with at least `capacity` slots (rounded up to a power of 2).
    pub fn new(capacity: usize) -> TraceRing {
        let cap = capacity.next_power_of_two().max(2);
        let slots = (0..cap)
            .map(|_| Slot {
                seq: AtomicU64::new(0),
                event: UnsafeCell::new(TraceEvent {
                    event_time_us: 0,
                    event_type: super::EventType::OpenStart,
                    node_id: 0,
                    stream_id: 0,
                    packet_ts: 0,
                    packet_data_id: 0,
                    thread_id: 0,
                }),
            })
            .collect::<Vec<_>>()
            .into_boxed_slice();
        TraceRing {
            slots,
            mask: (cap - 1) as u64,
            head: AtomicU64::new(0),
        }
    }

    pub fn capacity(&self) -> usize {
        self.slots.len()
    }

    /// Record one event: one atomic RMW + one slot write. Lock-free.
    #[inline]
    pub fn push(&self, ev: TraceEvent) {
        let idx = self.head.fetch_add(1, Ordering::Relaxed);
        let slot = &self.slots[(idx & self.mask) as usize];
        // Mark the slot as "being written" by clearing seq first so a
        // concurrent snapshot can detect the tear.
        slot.seq.store(0, Ordering::Release);
        // SAFETY: the slot is exclusively ours until we publish seq;
        // competing writers that lapped us would also clear seq first,
        // making the data invalid rather than torn-and-trusted.
        unsafe {
            *slot.event.get() = ev;
        }
        slot.seq.store(idx + 1, Ordering::Release);
    }

    /// Number of events written in total.
    pub fn written(&self) -> u64 {
        self.head.load(Ordering::Acquire)
    }

    /// Events lost to wrap-around.
    pub fn overwritten(&self) -> u64 {
        self.written().saturating_sub(self.slots.len() as u64)
    }

    /// Best-effort snapshot of currently held events (stable when the
    /// writers are quiescent, which is how the profiler uses it).
    pub fn snapshot(&self) -> Vec<TraceEvent> {
        let mut out = Vec::new();
        for slot in self.slots.iter() {
            let seq_before = slot.seq.load(Ordering::Acquire);
            if seq_before == 0 {
                continue; // unwritten or mid-write
            }
            // SAFETY: validated by re-reading seq below.
            let ev = unsafe { *slot.event.get() };
            let seq_after = slot.seq.load(Ordering::Acquire);
            if seq_before == seq_after {
                out.push((seq_before, ev));
            }
        }
        // Order by claim index for stable cross-slot ordering.
        out.sort_by_key(|(seq, _)| *seq);
        out.into_iter().map(|(_, ev)| ev).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::super::EventType;
    use super::*;
    use std::sync::Arc;

    fn ev(i: u64) -> TraceEvent {
        TraceEvent {
            event_time_us: i,
            event_type: EventType::PacketAdded,
            node_id: 0,
            stream_id: 0,
            packet_ts: i as i64,
            packet_data_id: i,
            thread_id: 0,
        }
    }

    #[test]
    fn capacity_rounds_to_power_of_two() {
        assert_eq!(TraceRing::new(100).capacity(), 128);
        assert_eq!(TraceRing::new(1).capacity(), 2);
    }

    #[test]
    fn wraparound_keeps_latest() {
        let r = TraceRing::new(4);
        for i in 0..10 {
            r.push(ev(i));
        }
        let snap = r.snapshot();
        assert_eq!(snap.len(), 4);
        let ids: Vec<u64> = snap.iter().map(|e| e.packet_data_id).collect();
        assert_eq!(ids, vec![6, 7, 8, 9]);
        assert_eq!(r.overwritten(), 6);
    }

    #[test]
    fn under_capacity_keeps_all_in_order() {
        let r = TraceRing::new(16);
        for i in 0..5 {
            r.push(ev(i));
        }
        let snap = r.snapshot();
        assert_eq!(
            snap.iter().map(|e| e.packet_data_id).collect::<Vec<_>>(),
            vec![0, 1, 2, 3, 4]
        );
        assert_eq!(r.overwritten(), 0);
    }

    #[test]
    fn multithreaded_stress_no_loss_under_capacity() {
        let r = Arc::new(TraceRing::new(1 << 13)); // 8192 >= 8 * 1000
        let mut hs = Vec::new();
        for t in 0..8u64 {
            let r2 = Arc::clone(&r);
            hs.push(std::thread::spawn(move || {
                for i in 0..1000u64 {
                    r2.push(ev(t * 1000 + i));
                }
            }));
        }
        for h in hs {
            h.join().unwrap();
        }
        let snap = r.snapshot();
        assert_eq!(snap.len(), 8000);
        // Every event present exactly once.
        let mut ids: Vec<u64> = snap.iter().map(|e| e.packet_data_id).collect();
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len(), 8000);
    }
}
