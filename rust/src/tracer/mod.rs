//! The tracer module (§5.1): follows individual packets across a graph
//! and records timing events along the way.
//!
//! Each event records a [`TraceEvent`] with `event_time`,
//! `packet_timestamp`, `packet_data_id`, `node_id` and `stream_id` —
//! sufficient to follow the flow of data and execution across the graph.
//! Events land in a **mutex-free thread-safe circular buffer**
//! ([`ring::TraceRing`]) to avoid contention and minimize the impact on
//! timing measurements. Aggregation (histograms, critical path) happens
//! offline in [`profile`].

pub mod export;
pub mod profile;
pub mod ring;

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Instant;

use crate::timestamp::Timestamp;
use ring::TraceRing;

/// What happened (§5.1 lists packet-flow and calculator-execution
/// events; we add flow-control events used by the Fig. 3 benches).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
#[repr(u8)]
pub enum EventType {
    OpenStart = 0,
    OpenEnd = 1,
    ProcessStart = 2,
    ProcessEnd = 3,
    CloseStart = 4,
    CloseEnd = 5,
    /// A packet was added to a node's input-stream queue.
    PacketAdded = 6,
    /// A packet was emitted on a node's output stream.
    PacketEmitted = 7,
    /// A stream's timestamp bound advanced without a packet.
    BoundAdvanced = 8,
    /// A producer was throttled by back-pressure (§4.1.4).
    Throttled = 9,
    Unthrottled = 10,
    /// A packet was dropped by a flow-control node (§4.1.4).
    PacketDropped = 11,
    /// A graph-input packet entered the graph.
    GraphInput = 12,
    /// A packet reached a graph output observer.
    GraphOutput = 13,
}

impl EventType {
    pub fn from_u8(v: u8) -> Option<EventType> {
        use EventType::*;
        Some(match v {
            0 => OpenStart,
            1 => OpenEnd,
            2 => ProcessStart,
            3 => ProcessEnd,
            4 => CloseStart,
            5 => CloseEnd,
            6 => PacketAdded,
            7 => PacketEmitted,
            8 => BoundAdvanced,
            9 => Throttled,
            10 => Unthrottled,
            11 => PacketDropped,
            12 => GraphInput,
            13 => GraphOutput,
            _ => return None,
        })
    }

    pub fn name(self) -> &'static str {
        use EventType::*;
        match self {
            OpenStart => "open_start",
            OpenEnd => "open_end",
            ProcessStart => "process_start",
            ProcessEnd => "process_end",
            CloseStart => "close_start",
            CloseEnd => "close_end",
            PacketAdded => "packet_added",
            PacketEmitted => "packet_emitted",
            BoundAdvanced => "bound_advanced",
            Throttled => "throttled",
            Unthrottled => "unthrottled",
            PacketDropped => "packet_dropped",
            GraphInput => "graph_input",
            GraphOutput => "graph_output",
        }
    }
}

/// One recorded event (§5.1's TraceEvent structure).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct TraceEvent {
    /// Microseconds since the trace epoch.
    pub event_time_us: u64,
    pub event_type: EventType,
    /// Node index in the built graph (u32::MAX when not node-scoped).
    pub node_id: u32,
    /// Stream index (u32::MAX when not stream-scoped).
    pub stream_id: u32,
    /// Raw packet timestamp (synchronization key).
    pub packet_ts: i64,
    /// Payload identity, to follow one datum across the graph.
    pub packet_data_id: u64,
    /// Worker thread ordinal.
    pub thread_id: u32,
}

impl TraceEvent {
    pub const NO_NODE: u32 = u32::MAX;
    pub const NO_STREAM: u32 = u32::MAX;
}

/// The tracer attached to a graph run. Cheap to clone (Arc inside).
/// When disabled, `record` is a single atomic load — the paper's
/// "tracer module records timing information on demand" (it can also be
/// compiled out entirely with `--no-default-features`-style flags in
/// C++; here the disabled path is one branch).
#[derive(Clone)]
pub struct Tracer {
    inner: Arc<TracerInner>,
}

struct TracerInner {
    enabled: AtomicBool,
    epoch: Instant,
    ring: TraceRing,
    /// Node index -> name (filled at graph build for export).
    node_names: std::sync::RwLock<Vec<String>>,
    /// Stream index -> name.
    stream_names: std::sync::RwLock<Vec<String>>,
}

thread_local! {
    static THREAD_ORDINAL: u32 = {
        use std::sync::atomic::AtomicU32;
        static NEXT: AtomicU32 = AtomicU32::new(0);
        NEXT.fetch_add(1, Ordering::Relaxed)
    };
}

impl Tracer {
    /// A tracer with an event ring of `capacity` (rounded up to a power
    /// of two).
    pub fn new(capacity: usize) -> Tracer {
        Tracer {
            inner: Arc::new(TracerInner {
                enabled: AtomicBool::new(true),
                epoch: Instant::now(),
                ring: TraceRing::new(capacity),
                node_names: std::sync::RwLock::new(Vec::new()),
                stream_names: std::sync::RwLock::new(Vec::new()),
            }),
        }
    }

    /// A disabled tracer: `record` costs one atomic load.
    pub fn disabled() -> Tracer {
        let t = Tracer::new(2);
        t.inner.enabled.store(false, Ordering::Release);
        t
    }

    pub fn is_enabled(&self) -> bool {
        self.inner.enabled.load(Ordering::Acquire)
    }

    pub fn set_enabled(&self, on: bool) {
        self.inner.enabled.store(on, Ordering::Release);
    }

    /// Register graph metadata for export (called at graph build).
    pub fn set_names(&self, nodes: Vec<String>, streams: Vec<String>) {
        *self.inner.node_names.write().unwrap() = nodes;
        *self.inner.stream_names.write().unwrap() = streams;
    }

    pub fn node_names(&self) -> Vec<String> {
        self.inner.node_names.read().unwrap().clone()
    }

    pub fn stream_names(&self) -> Vec<String> {
        self.inner.stream_names.read().unwrap().clone()
    }

    /// Record one event. Hot path: one atomic load when disabled; one
    /// clock read + one atomic RMW + one slot write when enabled.
    #[inline]
    pub fn record(
        &self,
        event_type: EventType,
        node_id: u32,
        stream_id: u32,
        packet_ts: Timestamp,
        packet_data_id: u64,
    ) {
        if !self.is_enabled() {
            return;
        }
        let ev = TraceEvent {
            event_time_us: self.inner.epoch.elapsed().as_micros() as u64,
            event_type,
            node_id,
            stream_id,
            packet_ts: packet_ts.raw(),
            packet_data_id,
            thread_id: THREAD_ORDINAL.with(|t| *t),
        };
        self.inner.ring.push(ev);
    }

    /// Snapshot the buffered events in chronological order. Intended to
    /// be called when the graph is quiescent (after `wait_until_done`).
    pub fn snapshot(&self) -> Vec<TraceEvent> {
        let mut evs = self.inner.ring.snapshot();
        evs.sort_by_key(|e| e.event_time_us);
        evs
    }

    /// Number of events dropped due to ring wrap-around.
    pub fn dropped(&self) -> u64 {
        self.inner.ring.overwritten()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn record_and_snapshot() {
        let t = Tracer::new(128);
        t.record(EventType::ProcessStart, 3, 1, Timestamp::new(10), 42);
        t.record(EventType::ProcessEnd, 3, 1, Timestamp::new(10), 42);
        let evs = t.snapshot();
        assert_eq!(evs.len(), 2);
        assert_eq!(evs[0].event_type, EventType::ProcessStart);
        assert_eq!(evs[0].node_id, 3);
        assert_eq!(evs[0].packet_data_id, 42);
        assert!(evs[1].event_time_us >= evs[0].event_time_us);
    }

    #[test]
    fn disabled_records_nothing() {
        let t = Tracer::disabled();
        t.record(EventType::ProcessStart, 0, 0, Timestamp::new(0), 0);
        assert!(t.snapshot().is_empty());
    }

    #[test]
    fn toggling_on_demand() {
        let t = Tracer::new(16);
        t.set_enabled(false);
        t.record(EventType::ProcessStart, 0, 0, Timestamp::new(0), 1);
        t.set_enabled(true);
        t.record(EventType::ProcessEnd, 0, 0, Timestamp::new(0), 2);
        let evs = t.snapshot();
        assert_eq!(evs.len(), 1);
        assert_eq!(evs[0].packet_data_id, 2);
    }

    #[test]
    fn event_type_roundtrip() {
        for v in 0..=13u8 {
            let e = EventType::from_u8(v).unwrap();
            assert_eq!(e as u8, v);
            assert!(!e.name().is_empty());
        }
        assert!(EventType::from_u8(200).is_none());
    }

    #[test]
    fn concurrent_recording_is_safe() {
        let t = Tracer::new(1 << 12);
        let mut handles = Vec::new();
        for thread in 0..4 {
            let t2 = t.clone();
            handles.push(std::thread::spawn(move || {
                for i in 0..500u64 {
                    t2.record(
                        EventType::PacketAdded,
                        thread,
                        0,
                        Timestamp::new(i as i64),
                        i,
                    );
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(t.snapshot().len(), 2000);
        assert_eq!(t.dropped(), 0);
    }
}
