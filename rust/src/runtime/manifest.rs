//! The artifact manifest: `python/compile/aot.py` writes
//! `artifacts/manifest.txt` describing every lowered model's I/O
//! signature; the rust runtime reads it to validate tensors at the
//! boundary. Deliberately a trivial line format (no JSON dependency):
//!
//! ```text
//! # mp-artifacts v1
//! model detector detector.hlo.txt
//! input image f32 1,32,32,1
//! output boxes f32 48,4
//! output scores f32 48
//! endmodel
//! ```

use crate::error::{MpError, MpResult};

/// One tensor port of a model.
#[derive(Clone, Debug, PartialEq)]
pub struct TensorSpec {
    pub name: String,
    pub dtype: String,
    pub shape: Vec<usize>,
}

/// One model entry.
#[derive(Clone, Debug, PartialEq, Default)]
pub struct ModelSpec {
    pub name: String,
    pub hlo_file: String,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

/// The parsed manifest.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Manifest {
    pub models: Vec<ModelSpec>,
}

impl Manifest {
    pub fn parse(text: &str) -> MpResult<Manifest> {
        let mut m = Manifest::default();
        let mut cur: Option<ModelSpec> = None;
        for (ln, raw) in text.lines().enumerate() {
            let line = raw.trim();
            let err = |msg: &str| MpError::Parse {
                line: ln + 1,
                message: msg.to_string(),
            };
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let parts: Vec<&str> = line.split_whitespace().collect();
            match parts[0] {
                "model" => {
                    if cur.is_some() {
                        return Err(err("nested model"));
                    }
                    if parts.len() != 3 {
                        return Err(err("model needs: model <name> <hlo-file>"));
                    }
                    cur = Some(ModelSpec {
                        name: parts[1].to_string(),
                        hlo_file: parts[2].to_string(),
                        ..Default::default()
                    });
                }
                "input" | "output" => {
                    let model = cur.as_mut().ok_or_else(|| err("tensor outside model"))?;
                    if parts.len() != 4 {
                        return Err(err("tensor needs: input|output <name> <dtype> <d0,d1,..>"));
                    }
                    let shape: Result<Vec<usize>, _> =
                        parts[3].split(',').map(|d| d.parse::<usize>()).collect();
                    let spec = TensorSpec {
                        name: parts[1].to_string(),
                        dtype: parts[2].to_string(),
                        shape: shape.map_err(|_| err("bad shape"))?,
                    };
                    if parts[0] == "input" {
                        model.inputs.push(spec);
                    } else {
                        model.outputs.push(spec);
                    }
                }
                "endmodel" => {
                    let model = cur.take().ok_or_else(|| err("endmodel without model"))?;
                    m.models.push(model);
                }
                other => return Err(err(&format!("unknown directive '{other}'"))),
            }
        }
        if cur.is_some() {
            return Err(MpError::Parse {
                line: 0,
                message: "unterminated model".into(),
            });
        }
        Ok(m)
    }

    pub fn load(path: &str) -> MpResult<Manifest> {
        Manifest::parse(&std::fs::read_to_string(path)?)
    }

    pub fn get(&self, name: &str) -> Option<&ModelSpec> {
        self.models.iter().find(|m| m.name == name)
    }

    pub fn to_text(&self) -> String {
        let mut out = String::from("# mp-artifacts v1\n");
        for m in &self.models {
            out.push_str(&format!("model {} {}\n", m.name, m.hlo_file));
            for t in &m.inputs {
                out.push_str(&format!(
                    "input {} {} {}\n",
                    t.name,
                    t.dtype,
                    t.shape.iter().map(|d| d.to_string()).collect::<Vec<_>>().join(",")
                ));
            }
            for t in &m.outputs {
                out.push_str(&format!(
                    "output {} {} {}\n",
                    t.name,
                    t.dtype,
                    t.shape.iter().map(|d| d.to_string()).collect::<Vec<_>>().join(",")
                ));
            }
            out.push_str("endmodel\n");
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
# mp-artifacts v1
model detector detector.hlo.txt
input image f32 1,32,32,1
output boxes f32 48,4
output scores f32 48
endmodel
model landmark landmark.hlo.txt
input face f32 1,24,24,1
output points f32 10,2
endmodel
"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.models.len(), 2);
        let d = m.get("detector").unwrap();
        assert_eq!(d.hlo_file, "detector.hlo.txt");
        assert_eq!(d.inputs[0].shape, vec![1, 32, 32, 1]);
        assert_eq!(d.outputs.len(), 2);
        assert!(m.get("nope").is_none());
    }

    #[test]
    fn roundtrip() {
        let m = Manifest::parse(SAMPLE).unwrap();
        let m2 = Manifest::parse(&m.to_text()).unwrap();
        assert_eq!(m, m2);
    }

    #[test]
    fn rejects_malformed() {
        assert!(Manifest::parse("model onlyname\n").is_err());
        assert!(Manifest::parse("input x f32 1,2\n").is_err());
        assert!(Manifest::parse("model a b\ninput x f32 a,b\nendmodel\n").is_err());
        assert!(Manifest::parse("model a b\n").is_err()); // unterminated
        assert!(Manifest::parse("bogus\n").is_err());
        assert!(Manifest::parse("model a b\nmodel c d\n").is_err()); // nested
        assert!(Manifest::parse("endmodel\n").is_err());
    }
}
