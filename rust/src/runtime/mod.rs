//! The XLA/PJRT runtime: loads AOT-compiled HLO-text artifacts produced
//! by `python/compile/aot.py` and executes them on the request path —
//! Python is never involved at run time.
//!
//! The `xla` crate's `PjRtClient` is `Rc`-based (not `Send`), so the
//! runtime runs a **dedicated inference-service thread** that owns the
//! client and all compiled executables; calculators talk to it through
//! a channel. This mirrors the paper's own deployment advice (§3.6):
//! "attaching a heavy model-inference calculator to a separate executor
//! can improve the performance of a real-time application".

pub mod manifest;

use std::collections::HashMap;
use std::sync::mpsc;
use std::sync::{Arc, Mutex};

use crate::error::{MpError, MpResult};
pub use manifest::{Manifest, ModelSpec, TensorSpec};

/// A dense f32 tensor (the only dtype our models exchange at the
/// boundary; bf16/int8 live inside the HLO).
#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Tensor {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        Tensor { shape, data }
    }

    pub fn zeros(shape: Vec<usize>) -> Tensor {
        let n = shape.iter().product();
        Tensor {
            shape,
            data: vec![0.0; n],
        }
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }
}

enum Request {
    Infer {
        model: String,
        inputs: Vec<Tensor>,
        reply: mpsc::Sender<MpResult<Vec<Tensor>>>,
    },
    ListModels {
        reply: mpsc::Sender<Vec<String>>,
    },
    Shutdown,
}

/// Cloneable handle to the inference service. Safe to stash in a side
/// packet and share across calculators/threads.
#[derive(Clone)]
pub struct InferenceEngine {
    tx: mpsc::Sender<Request>,
    // Keep a liveness guard so the service stops when the last handle
    // drops.
    _guard: Arc<EngineGuard>,
}

struct EngineGuard {
    tx: mpsc::Sender<Request>,
}

impl Drop for EngineGuard {
    fn drop(&mut self) {
        let _ = self.tx.send(Request::Shutdown);
    }
}

impl InferenceEngine {
    /// Start the service: load the manifest in `artifact_dir`, compile
    /// every listed model on the PJRT CPU client, and serve requests.
    pub fn start(artifact_dir: &str) -> MpResult<InferenceEngine> {
        let manifest = Manifest::load(&format!("{artifact_dir}/manifest.txt"))?;
        Self::start_with_manifest(artifact_dir, manifest)
    }

    /// Start with an explicit manifest (tests).
    pub fn start_with_manifest(
        artifact_dir: &str,
        manifest: Manifest,
    ) -> MpResult<InferenceEngine> {
        let (tx, rx) = mpsc::channel::<Request>();
        let (ready_tx, ready_rx) = mpsc::channel::<MpResult<()>>();
        let dir = artifact_dir.to_string();
        std::thread::Builder::new()
            .name("mp-inference".into())
            .spawn(move || service_main(dir, manifest, rx, ready_tx))
            .map_err(|e| MpError::Runtime(format!("spawn inference thread: {e}")))?;
        ready_rx
            .recv()
            .map_err(|_| MpError::Runtime("inference service died during init".into()))??;
        Ok(InferenceEngine {
            tx: tx.clone(),
            _guard: Arc::new(EngineGuard { tx }),
        })
    }

    /// Execute `model` on `inputs`. Blocks until the result is ready.
    pub fn infer(&self, model: &str, inputs: Vec<Tensor>) -> MpResult<Vec<Tensor>> {
        let (reply, rx) = mpsc::channel();
        self.tx
            .send(Request::Infer {
                model: model.to_string(),
                inputs,
                reply,
            })
            .map_err(|_| MpError::Runtime("inference service gone".into()))?;
        rx.recv()
            .map_err(|_| MpError::Runtime("inference service dropped request".into()))?
    }

    /// Names of the loaded models.
    pub fn models(&self) -> Vec<String> {
        let (reply, rx) = mpsc::channel();
        if self.tx.send(Request::ListModels { reply }).is_err() {
            return Vec::new();
        }
        rx.recv().unwrap_or_default()
    }
}

struct LoadedModel {
    exe: xla::PjRtLoadedExecutable,
    spec: ModelSpec,
}

fn service_main(
    dir: String,
    manifest: Manifest,
    rx: mpsc::Receiver<Request>,
    ready: mpsc::Sender<MpResult<()>>,
) {
    // Own the (non-Send) client on this thread.
    let client = match xla::PjRtClient::cpu() {
        Ok(c) => c,
        Err(e) => {
            let _ = ready.send(Err(MpError::Runtime(format!("PjRtClient::cpu: {e}"))));
            return;
        }
    };
    let mut models: HashMap<String, LoadedModel> = HashMap::new();
    for spec in manifest.models {
        let path = format!("{dir}/{}", spec.hlo_file);
        let load = (|| -> MpResult<xla::PjRtLoadedExecutable> {
            let proto = xla::HloModuleProto::from_text_file(&path)
                .map_err(|e| MpError::Runtime(format!("load {path}: {e}")))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            client
                .compile(&comp)
                .map_err(|e| MpError::Runtime(format!("compile {}: {e}", spec.name)))
        })();
        match load {
            Ok(exe) => {
                models.insert(spec.name.clone(), LoadedModel { exe, spec });
            }
            Err(e) => {
                let _ = ready.send(Err(e));
                return;
            }
        }
    }
    let _ = ready.send(Ok(()));

    while let Ok(req) = rx.recv() {
        match req {
            Request::Shutdown => break,
            Request::ListModels { reply } => {
                let mut names: Vec<String> = models.keys().cloned().collect();
                names.sort();
                let _ = reply.send(names);
            }
            Request::Infer {
                model,
                inputs,
                reply,
            } => {
                let _ = reply.send(run_model(&models, &model, inputs));
            }
        }
    }
}

fn run_model(
    models: &HashMap<String, LoadedModel>,
    model: &str,
    inputs: Vec<Tensor>,
) -> MpResult<Vec<Tensor>> {
    let m = models
        .get(model)
        .ok_or_else(|| MpError::Runtime(format!("unknown model '{model}'")))?;
    if inputs.len() != m.spec.inputs.len() {
        return Err(MpError::Runtime(format!(
            "model '{model}' expects {} inputs, got {}",
            m.spec.inputs.len(),
            inputs.len()
        )));
    }
    let mut literals = Vec::with_capacity(inputs.len());
    for (t, spec) in inputs.iter().zip(&m.spec.inputs) {
        let want: usize = spec.shape.iter().product();
        if t.data.len() != want {
            return Err(MpError::Runtime(format!(
                "model '{model}' input '{}' expects {:?} ({} elems), got {} elems",
                spec.name,
                spec.shape,
                want,
                t.data.len()
            )));
        }
        let dims: Vec<i64> = spec.shape.iter().map(|&d| d as i64).collect();
        let lit = xla::Literal::vec1(&t.data)
            .reshape(&dims)
            .map_err(|e| MpError::Runtime(format!("reshape input: {e}")))?;
        literals.push(lit);
    }
    let result = m
        .exe
        .execute::<xla::Literal>(&literals)
        .map_err(|e| MpError::Runtime(format!("execute '{model}': {e}")))?;
    let out = result[0][0]
        .to_literal_sync()
        .map_err(|e| MpError::Runtime(format!("fetch result: {e}")))?;
    // aot.py lowers with return_tuple=True: the output is always a tuple.
    let parts = out
        .to_tuple()
        .map_err(|e| MpError::Runtime(format!("untuple result: {e}")))?;
    if parts.len() != m.spec.outputs.len() {
        return Err(MpError::Runtime(format!(
            "model '{model}' declared {} outputs, produced {}",
            m.spec.outputs.len(),
            parts.len()
        )));
    }
    let mut tensors = Vec::with_capacity(parts.len());
    for (lit, spec) in parts.into_iter().zip(&m.spec.outputs) {
        let data = lit
            .to_vec::<f32>()
            .map_err(|e| MpError::Runtime(format!("read output '{}': {e}", spec.name)))?;
        tensors.push(Tensor::new(spec.shape.clone(), data));
    }
    Ok(tensors)
}

/// Global engine cache so multiple graphs/examples share one service
/// per artifact dir.
static ENGINES: once_cell::sync::Lazy<Mutex<HashMap<String, InferenceEngine>>> =
    once_cell::sync::Lazy::new(|| Mutex::new(HashMap::new()));

/// Get (or start) the shared engine for an artifact directory.
pub fn shared_engine(artifact_dir: &str) -> MpResult<InferenceEngine> {
    let mut map = ENGINES.lock().unwrap();
    if let Some(e) = map.get(artifact_dir) {
        return Ok(e.clone());
    }
    let e = InferenceEngine::start(artifact_dir)?;
    map.insert(artifact_dir.to_string(), e.clone());
    Ok(e)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tensor_shape_checks() {
        let t = Tensor::new(vec![2, 3], vec![0.0; 6]);
        assert_eq!(t.len(), 6);
        let z = Tensor::zeros(vec![4, 4]);
        assert_eq!(z.data.len(), 16);
    }

    #[test]
    #[should_panic]
    fn tensor_shape_mismatch_panics() {
        Tensor::new(vec![2, 2], vec![0.0; 5]);
    }

    #[test]
    fn missing_manifest_is_clean_error() {
        match InferenceEngine::start("/nonexistent/dir") {
            Err(e) => assert!(matches!(e, MpError::Io(_) | MpError::Runtime(_))),
            Ok(_) => panic!("expected an error"),
        }
    }

    // End-to-end engine tests live in rust/tests/runtime_e2e.rs and are
    // skipped when `make artifacts` has not run.
}
