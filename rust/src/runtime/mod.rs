//! The model runtime: loads the artifact manifest produced by
//! `python/compile/aot.py` and executes models on the request path —
//! Python is never involved at run time.
//!
//! Two backends, selected by the off-by-default `xla` cargo feature:
//!
//! * **`xla` enabled** — the real thing: HLO-text artifacts are compiled
//!   and executed through the PJRT C API (`xla` crate). The crate is
//!   not listed in `Cargo.toml` (it cannot be fetched in the offline
//!   build environment); enabling the feature requires adding a vendored
//!   `xla` dependency.
//! * **`xla` disabled (default)** — a deterministic *reference backend*:
//!   outputs have the manifest-declared shapes and are a fixed
//!   pseudo-random function of the inputs. It is NOT a numerical
//!   reproduction of the models — it exists so the full serving path
//!   (graph pool, batching, calculators, tracing) builds, runs and is
//!   testable offline. Tests that assert real model semantics live in
//!   `rust/tests/runtime_e2e.rs` and skip when artifacts are absent.
//!
//! Either way the service runs on a **dedicated inference-service
//! thread** that owns the loaded models; calculators talk to it through
//! a channel. This mirrors the paper's own deployment advice (§3.6):
//! "attaching a heavy model-inference calculator to a separate executor
//! can improve the performance of a real-time application".

pub mod manifest;

use std::collections::HashMap;
use std::sync::mpsc;
use std::sync::{Arc, Mutex};

use crate::error::{MpError, MpResult};
pub use manifest::{Manifest, ModelSpec, TensorSpec};

/// A dense f32 tensor (the only dtype our models exchange at the
/// boundary; bf16/int8 live inside the HLO).
#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Tensor {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        Tensor { shape, data }
    }

    pub fn zeros(shape: Vec<usize>) -> Tensor {
        let n = shape.iter().product();
        Tensor {
            shape,
            data: vec![0.0; n],
        }
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }
}

enum Request {
    Infer {
        model: String,
        inputs: Vec<Tensor>,
        reply: mpsc::Sender<MpResult<Vec<Tensor>>>,
    },
    ListModels {
        reply: mpsc::Sender<Vec<String>>,
    },
    Shutdown,
}

/// Cloneable handle to the inference service. Safe to stash in a side
/// packet and share across calculators/threads.
#[derive(Clone)]
pub struct InferenceEngine {
    tx: mpsc::Sender<Request>,
    // Keep a liveness guard so the service stops when the last handle
    // drops.
    _guard: Arc<EngineGuard>,
}

struct EngineGuard {
    tx: mpsc::Sender<Request>,
}

impl Drop for EngineGuard {
    fn drop(&mut self) {
        let _ = self.tx.send(Request::Shutdown);
    }
}

impl InferenceEngine {
    /// Start the service: load the manifest in `artifact_dir`, compile
    /// every listed model on the PJRT CPU client, and serve requests.
    pub fn start(artifact_dir: &str) -> MpResult<InferenceEngine> {
        let manifest = Manifest::load(&format!("{artifact_dir}/manifest.txt"))?;
        Self::start_with_manifest(artifact_dir, manifest)
    }

    /// Start with an explicit manifest (tests).
    pub fn start_with_manifest(
        artifact_dir: &str,
        manifest: Manifest,
    ) -> MpResult<InferenceEngine> {
        let (tx, rx) = mpsc::channel::<Request>();
        let (ready_tx, ready_rx) = mpsc::channel::<MpResult<()>>();
        let dir = artifact_dir.to_string();
        std::thread::Builder::new()
            .name("mp-inference".into())
            .spawn(move || service_main(dir, manifest, rx, ready_tx))
            .map_err(|e| MpError::Runtime(format!("spawn inference thread: {e}")))?;
        ready_rx
            .recv()
            .map_err(|_| MpError::Runtime("inference service died during init".into()))??;
        Ok(InferenceEngine {
            tx: tx.clone(),
            _guard: Arc::new(EngineGuard { tx }),
        })
    }

    /// Execute `model` on `inputs`. Blocks until the result is ready.
    pub fn infer(&self, model: &str, inputs: Vec<Tensor>) -> MpResult<Vec<Tensor>> {
        let (reply, rx) = mpsc::channel();
        self.tx
            .send(Request::Infer {
                model: model.to_string(),
                inputs,
                reply,
            })
            .map_err(|_| MpError::Runtime("inference service gone".into()))?;
        rx.recv()
            .map_err(|_| MpError::Runtime("inference service dropped request".into()))?
    }

    /// Names of the loaded models.
    pub fn models(&self) -> Vec<String> {
        let (reply, rx) = mpsc::channel();
        if self.tx.send(Request::ListModels { reply }).is_err() {
            return Vec::new();
        }
        rx.recv().unwrap_or_default()
    }
}

#[cfg(feature = "xla")]
struct LoadedModel {
    exe: xla::PjRtLoadedExecutable,
    spec: ModelSpec,
}

#[cfg(not(feature = "xla"))]
struct LoadedModel {
    spec: ModelSpec,
}

fn service_main(
    dir: String,
    manifest: Manifest,
    rx: mpsc::Receiver<Request>,
    ready: mpsc::Sender<MpResult<()>>,
) {
    #[cfg(not(feature = "xla"))]
    let _ = &dir; // the reference backend needs only the manifest
    // With the xla feature: own the (non-Send) PJRT client on this
    // thread and compile every model up front.
    #[cfg(feature = "xla")]
    let client = match xla::PjRtClient::cpu() {
        Ok(c) => c,
        Err(e) => {
            let _ = ready.send(Err(MpError::Runtime(format!("PjRtClient::cpu: {e}"))));
            return;
        }
    };
    let mut models: HashMap<String, LoadedModel> = HashMap::new();
    for spec in manifest.models {
        #[cfg(feature = "xla")]
        {
            let path = format!("{dir}/{}", spec.hlo_file);
            let load = (|| -> MpResult<xla::PjRtLoadedExecutable> {
                let proto = xla::HloModuleProto::from_text_file(&path)
                    .map_err(|e| MpError::Runtime(format!("load {path}: {e}")))?;
                let comp = xla::XlaComputation::from_proto(&proto);
                client
                    .compile(&comp)
                    .map_err(|e| MpError::Runtime(format!("compile {}: {e}", spec.name)))
            })();
            match load {
                Ok(exe) => {
                    models.insert(spec.name.clone(), LoadedModel { exe, spec });
                }
                Err(e) => {
                    let _ = ready.send(Err(e));
                    return;
                }
            }
        }
        #[cfg(not(feature = "xla"))]
        {
            models.insert(spec.name.clone(), LoadedModel { spec });
        }
    }
    let _ = ready.send(Ok(()));

    while let Ok(req) = rx.recv() {
        match req {
            Request::Shutdown => break,
            Request::ListModels { reply } => {
                let mut names: Vec<String> = models.keys().cloned().collect();
                names.sort();
                let _ = reply.send(names);
            }
            Request::Infer {
                model,
                inputs,
                reply,
            } => {
                let _ = reply.send(run_model(&models, &model, inputs));
            }
        }
    }
}

fn run_model(
    models: &HashMap<String, LoadedModel>,
    model: &str,
    inputs: Vec<Tensor>,
) -> MpResult<Vec<Tensor>> {
    let m = models
        .get(model)
        .ok_or_else(|| MpError::Runtime(format!("unknown model '{model}'")))?;
    if inputs.len() != m.spec.inputs.len() {
        return Err(MpError::Runtime(format!(
            "model '{model}' expects {} inputs, got {}",
            m.spec.inputs.len(),
            inputs.len()
        )));
    }
    for (t, spec) in inputs.iter().zip(&m.spec.inputs) {
        let want: usize = spec.shape.iter().product();
        if t.data.len() != want {
            return Err(MpError::Runtime(format!(
                "model '{model}' input '{}' expects {:?} ({} elems), got {} elems",
                spec.name,
                spec.shape,
                want,
                t.data.len()
            )));
        }
    }
    #[cfg(feature = "xla")]
    {
        let mut literals = Vec::with_capacity(inputs.len());
        for (t, spec) in inputs.iter().zip(&m.spec.inputs) {
            let dims: Vec<i64> = spec.shape.iter().map(|&d| d as i64).collect();
            let lit = xla::Literal::vec1(&t.data)
                .reshape(&dims)
                .map_err(|e| MpError::Runtime(format!("reshape input: {e}")))?;
            literals.push(lit);
        }
        let result = m
            .exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| MpError::Runtime(format!("execute '{model}': {e}")))?;
        let out = result[0][0]
            .to_literal_sync()
            .map_err(|e| MpError::Runtime(format!("fetch result: {e}")))?;
        // aot.py lowers with return_tuple=True: the output is always a tuple.
        let parts = out
            .to_tuple()
            .map_err(|e| MpError::Runtime(format!("untuple result: {e}")))?;
        if parts.len() != m.spec.outputs.len() {
            return Err(MpError::Runtime(format!(
                "model '{model}' declared {} outputs, produced {}",
                m.spec.outputs.len(),
                parts.len()
            )));
        }
        let mut tensors = Vec::with_capacity(parts.len());
        for (lit, spec) in parts.into_iter().zip(&m.spec.outputs) {
            let data = lit
                .to_vec::<f32>()
                .map_err(|e| MpError::Runtime(format!("read output '{}': {e}", spec.name)))?;
            tensors.push(Tensor::new(spec.shape.clone(), data));
        }
        Ok(tensors)
    }
    #[cfg(not(feature = "xla"))]
    {
        Ok(reference_outputs(&m.spec, &inputs))
    }
}

/// The reference backend's "model": every output element is a fixed
/// pseudo-random function (in `[0, 1)`) of an input checksum and its own
/// index, so results are deterministic, shape-correct, sensitive to the
/// input, and score-like enough to flow through detection decoding.
#[cfg(not(feature = "xla"))]
fn reference_outputs(spec: &ModelSpec, inputs: &[Tensor]) -> Vec<Tensor> {
    let mut checksum = 0.0f64;
    for t in inputs {
        for (i, v) in t.data.iter().enumerate() {
            checksum += (*v as f64) * (((i % 97) + 1) as f64) * 1e-3;
        }
    }
    spec.outputs
        .iter()
        .enumerate()
        .map(|(oi, os)| {
            let n: usize = os.shape.iter().product();
            let data = (0..n)
                .map(|i| {
                    let x = (checksum + (oi * 10_000 + i) as f64 * 0.618_033_988_7).sin();
                    (x * 0.5 + 0.5) as f32
                })
                .collect();
            Tensor::new(os.shape.clone(), data)
        })
        .collect()
}

/// Global engine cache so multiple graphs/examples share one service
/// per artifact dir.
static ENGINES: std::sync::OnceLock<Mutex<HashMap<String, InferenceEngine>>> =
    std::sync::OnceLock::new();

/// Get (or start) the shared engine for an artifact directory.
pub fn shared_engine(artifact_dir: &str) -> MpResult<InferenceEngine> {
    let mut map = ENGINES
        .get_or_init(|| Mutex::new(HashMap::new()))
        .lock()
        .unwrap();
    if let Some(e) = map.get(artifact_dir) {
        return Ok(e.clone());
    }
    let e = InferenceEngine::start(artifact_dir)?;
    map.insert(artifact_dir.to_string(), e.clone());
    Ok(e)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tensor_shape_checks() {
        let t = Tensor::new(vec![2, 3], vec![0.0; 6]);
        assert_eq!(t.len(), 6);
        let z = Tensor::zeros(vec![4, 4]);
        assert_eq!(z.data.len(), 16);
    }

    #[test]
    #[should_panic]
    fn tensor_shape_mismatch_panics() {
        Tensor::new(vec![2, 2], vec![0.0; 5]);
    }

    #[test]
    fn missing_manifest_is_clean_error() {
        match InferenceEngine::start("/nonexistent/dir") {
            Err(e) => assert!(matches!(e, MpError::Io(_) | MpError::Runtime(_))),
            Ok(_) => panic!("expected an error"),
        }
    }

    #[cfg(not(feature = "xla"))]
    #[test]
    fn reference_backend_serves_manifest_models() {
        let manifest = Manifest::parse(
            "model toy toy.hlo.txt\ninput x f32 2,3\noutput y f32 4\noutput z f32 2,2\nendmodel\n",
        )
        .unwrap();
        let engine =
            InferenceEngine::start_with_manifest("/nonexistent/ref-backend", manifest).unwrap();
        assert_eq!(engine.models(), vec!["toy".to_string()]);
        let input = Tensor::new(vec![2, 3], vec![0.5; 6]);
        let out = engine.infer("toy", vec![input.clone()]).unwrap();
        assert_eq!(out.len(), 2);
        assert_eq!(out[0].shape, vec![4]);
        assert_eq!(out[1].shape, vec![2, 2]);
        assert!(out[0].data.iter().all(|v| (0.0..1.0).contains(v)));
        // Deterministic: same input, same output.
        let again = engine.infer("toy", vec![input]).unwrap();
        assert_eq!(out, again);
        // Sensitive to the input.
        let other = engine
            .infer("toy", vec![Tensor::new(vec![2, 3], vec![0.9; 6])])
            .unwrap();
        assert_ne!(out, other);
        // Shape mismatch still rejected.
        assert!(engine
            .infer("toy", vec![Tensor::new(vec![5], vec![0.0; 5])])
            .is_err());
    }

    // End-to-end engine tests live in rust/tests/runtime_e2e.rs and are
    // skipped when `make artifacts` has not run.
}
