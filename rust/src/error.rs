//! Framework-wide error type.
//!
//! MediaPipe reports graph failures as a single status propagated out of
//! `Graph::wait_until_done()`; any calculator error terminates the graph
//! run (§3.5). We mirror that with one `MpError` enum used across the
//! framework, and a `MpResult<T>` alias.

use thiserror::Error;

/// Result alias used across the framework.
pub type MpResult<T> = Result<T, MpError>;

/// Framework-wide error type.
#[derive(Error, Debug, Clone)]
pub enum MpError {
    /// Graph configuration failed validation (§3.5: stream produced by
    /// more than one source, type mismatch, contract violation, ...).
    #[error("graph validation error: {0}")]
    Validation(String),

    /// GraphConfig text could not be parsed.
    #[error("config parse error at line {line}: {message}")]
    Parse { line: usize, message: String },

    /// A calculator name was not found in the registry.
    #[error("unknown calculator type: {0}")]
    UnknownCalculator(String),

    /// A subgraph type was not found in the subgraph registry.
    #[error("unknown subgraph type: {0}")]
    UnknownSubgraph(String),

    /// Packet payload was accessed with the wrong type.
    #[error("packet type mismatch: expected {expected}, got {actual}")]
    PacketTypeMismatch {
        expected: &'static str,
        actual: &'static str,
    },

    /// Attempted to read an empty packet (no payload at this timestamp).
    #[error("empty packet")]
    EmptyPacket,

    /// A packet violated the monotonically-increasing timestamp
    /// requirement on a stream (§4.1.2).
    #[error("timestamp violation on stream '{stream}': packet ts {packet_ts} < bound {bound}")]
    TimestampViolation {
        stream: String,
        packet_ts: i64,
        bound: i64,
    },

    /// A calculator returned an error from Open(); terminates the run.
    #[error("calculator '{node}' failed in Open(): {message}")]
    OpenFailed { node: String, message: String },

    /// A calculator returned an error from Process(); the framework calls
    /// Close() and the graph run terminates (§3.4).
    #[error("calculator '{node}' failed in Process(): {message}")]
    ProcessFailed { node: String, message: String },

    /// A calculator returned an error from Close().
    #[error("calculator '{node}' failed in Close(): {message}")]
    CloseFailed { node: String, message: String },

    /// Side packet requested by a calculator was not provided.
    #[error("missing side packet '{0}'")]
    MissingSidePacket(String),

    /// Graph input stream operations after the graph finished, etc.
    #[error("invalid graph state: {0}")]
    InvalidState(String),

    /// Runtime (PJRT / XLA artifact) failures.
    #[error("runtime error: {0}")]
    Runtime(String),

    /// I/O wrapper (trace export, artifact load, ...).
    #[error("io error: {0}")]
    Io(String),

    /// Catch-all for calculator-internal errors.
    #[error("{0}")]
    Internal(String),
}

impl MpError {
    /// Convenience constructor used by calculators.
    pub fn internal(msg: impl Into<String>) -> Self {
        MpError::Internal(msg.into())
    }
}

impl From<std::io::Error> for MpError {
    fn from(e: std::io::Error) -> Self {
        MpError::Io(e.to_string())
    }
}

impl From<anyhow::Error> for MpError {
    fn from(e: anyhow::Error) -> Self {
        MpError::Internal(format!("{e:#}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_includes_node_name() {
        let e = MpError::ProcessFailed {
            node: "detector".into(),
            message: "boom".into(),
        };
        let s = e.to_string();
        assert!(s.contains("detector"));
        assert!(s.contains("boom"));
    }

    #[test]
    fn io_error_converts() {
        let io = std::io::Error::new(std::io::ErrorKind::NotFound, "nope");
        let e: MpError = io.into();
        assert!(matches!(e, MpError::Io(_)));
    }

    #[test]
    fn errors_are_cloneable_for_fanout() {
        // The graph clones the terminating error into every waiter.
        let e = MpError::Validation("dup stream".into());
        let e2 = e.clone();
        assert_eq!(e.to_string(), e2.to_string());
    }
}
