//! Framework-wide error type.
//!
//! MediaPipe reports graph failures as a single status propagated out of
//! `Graph::wait_until_done()`; any calculator error terminates the graph
//! run (§3.5). We mirror that with one `MpError` enum used across the
//! framework, and a `MpResult<T>` alias. `Display` and `std::error::Error`
//! are implemented by hand — the crate builds offline with zero
//! dependencies.

use std::fmt;

/// Result alias used across the framework.
pub type MpResult<T> = Result<T, MpError>;

/// Framework-wide error type.
#[derive(Debug, Clone)]
pub enum MpError {
    /// Graph configuration failed validation (§3.5: stream produced by
    /// more than one source, type mismatch, contract violation, ...).
    Validation(String),

    /// GraphConfig text could not be parsed.
    Parse { line: usize, message: String },

    /// A calculator name was not found in the registry.
    UnknownCalculator(String),

    /// A subgraph type was not found in the subgraph registry.
    UnknownSubgraph(String),

    /// Packet payload was accessed with the wrong type.
    PacketTypeMismatch {
        expected: &'static str,
        actual: &'static str,
    },

    /// Attempted to read an empty packet (no payload at this timestamp).
    EmptyPacket,

    /// A packet violated the monotonically-increasing timestamp
    /// requirement on a stream (§4.1.2).
    TimestampViolation {
        stream: String,
        packet_ts: i64,
        bound: i64,
    },

    /// A calculator returned an error from Open(); terminates the run.
    OpenFailed { node: String, message: String },

    /// A calculator returned an error from Process(); the framework calls
    /// Close() and the graph run terminates (§3.4).
    ProcessFailed { node: String, message: String },

    /// A calculator returned an error from Close().
    CloseFailed { node: String, message: String },

    /// Side packet requested by a calculator was not provided.
    MissingSidePacket(String),

    /// Graph input stream operations after the graph finished, etc.
    InvalidState(String),

    /// The serving layer refused the request at admission: queue depth ×
    /// observed batch latency implies the request's deadline (or the
    /// configured queue bound) cannot be met, so the server sheds the
    /// load instead of queueing it (flow control extended to the serving
    /// boundary — the caller should back off or retry elsewhere).
    Overloaded {
        /// Jobs already queued ahead of the rejected request.
        queued: usize,
        /// Estimated wait (µs) the request would have faced; 0 when the
        /// rejection came from the hard queue-depth cap.
        estimated_wait_us: u64,
    },

    /// The request's deadline passed before the server could dispatch
    /// it; the job was expired from the queue without touching a graph.
    DeadlineExceeded {
        /// How long the request sat in the server (µs) before expiry.
        waited_us: u64,
    },

    /// The worker process serving this request's session died (or was
    /// drained) with the request in flight. The session has been retired
    /// and rerouted to a healthy worker; the caller should retry — the
    /// retry lands on the new worker.
    WorkerLost {
        /// The lost worker's address (as configured at the router).
        worker: String,
    },

    /// Runtime (model backend / artifact) failures.
    Runtime(String),

    /// I/O wrapper (trace export, artifact load, ...).
    Io(String),

    /// Catch-all for calculator-internal errors.
    Internal(String),
}

impl fmt::Display for MpError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            MpError::Validation(m) => write!(f, "graph validation error: {m}"),
            MpError::Parse { line, message } => {
                write!(f, "config parse error at line {line}: {message}")
            }
            MpError::UnknownCalculator(n) => write!(f, "unknown calculator type: {n}"),
            MpError::UnknownSubgraph(n) => write!(f, "unknown subgraph type: {n}"),
            MpError::PacketTypeMismatch { expected, actual } => {
                write!(f, "packet type mismatch: expected {expected}, got {actual}")
            }
            MpError::EmptyPacket => write!(f, "empty packet"),
            MpError::TimestampViolation {
                stream,
                packet_ts,
                bound,
            } => write!(
                f,
                "timestamp violation on stream '{stream}': packet ts {packet_ts} < bound {bound}"
            ),
            MpError::OpenFailed { node, message } => {
                write!(f, "calculator '{node}' failed in Open(): {message}")
            }
            MpError::ProcessFailed { node, message } => {
                write!(f, "calculator '{node}' failed in Process(): {message}")
            }
            MpError::CloseFailed { node, message } => {
                write!(f, "calculator '{node}' failed in Close(): {message}")
            }
            MpError::MissingSidePacket(n) => write!(f, "missing side packet '{n}'"),
            MpError::InvalidState(m) => write!(f, "invalid graph state: {m}"),
            MpError::Overloaded {
                queued,
                estimated_wait_us,
            } => write!(
                f,
                "server overloaded: request shed at admission ({queued} jobs queued, \
                 estimated wait {estimated_wait_us}µs)"
            ),
            MpError::DeadlineExceeded { waited_us } => write!(
                f,
                "request deadline exceeded after {waited_us}µs in queue"
            ),
            MpError::WorkerLost { worker } => write!(
                f,
                "worker '{worker}' lost with this request in flight; session rerouted — retry"
            ),
            MpError::Runtime(m) => write!(f, "runtime error: {m}"),
            MpError::Io(m) => write!(f, "io error: {m}"),
            MpError::Internal(m) => write!(f, "{m}"),
        }
    }
}

impl std::error::Error for MpError {}

impl MpError {
    /// Convenience constructor used by calculators.
    pub fn internal(msg: impl Into<String>) -> Self {
        MpError::Internal(msg.into())
    }
}

impl From<std::io::Error> for MpError {
    fn from(e: std::io::Error) -> Self {
        MpError::Io(e.to_string())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_includes_node_name() {
        let e = MpError::ProcessFailed {
            node: "detector".into(),
            message: "boom".into(),
        };
        let s = e.to_string();
        assert!(s.contains("detector"));
        assert!(s.contains("boom"));
    }

    #[test]
    fn io_error_converts() {
        let io = std::io::Error::new(std::io::ErrorKind::NotFound, "nope");
        let e: MpError = io.into();
        assert!(matches!(e, MpError::Io(_)));
    }

    #[test]
    fn errors_are_cloneable_for_fanout() {
        // The graph clones the terminating error into every waiter.
        let e = MpError::Validation("dup stream".into());
        let e2 = e.clone();
        assert_eq!(e.to_string(), e2.to_string());
    }

    #[test]
    fn overload_errors_are_typed_and_matchable() {
        // Callers shed-aware retry logic matches on the variant, not on
        // display strings — both variants must survive a clone round-trip.
        let shed = MpError::Overloaded {
            queued: 17,
            estimated_wait_us: 42_000,
        };
        assert!(matches!(
            shed.clone(),
            MpError::Overloaded { queued: 17, .. }
        ));
        assert!(shed.to_string().contains("17"));
        let late = MpError::DeadlineExceeded { waited_us: 9_000 };
        assert!(matches!(
            late.clone(),
            MpError::DeadlineExceeded { waited_us: 9_000 }
        ));
        assert!(late.to_string().contains("9000"));
        let lost = MpError::WorkerLost {
            worker: "127.0.0.1:9901".into(),
        };
        assert!(matches!(lost.clone(), MpError::WorkerLost { .. }));
        assert!(lost.to_string().contains("127.0.0.1:9901"));
    }

    #[test]
    fn implements_std_error() {
        fn takes_err(_: &dyn std::error::Error) {}
        takes_err(&MpError::EmptyPacket);
    }
}
