//! Small synchronization helpers shared across the crate.
//!
//! The one that matters: [`lock_recover`]. A `Mutex` is *poisoned* when
//! a thread panics while holding its guard; every later
//! `lock().unwrap()` then panics too, cascading one thread's bug into
//! every caller that touches the same state. That trade is right only
//! when a panic can leave the protected state half-updated. The shared
//! state guarded by the serving layer's locks — ready queues, standby
//! slots, demux maps, metric reservoirs — consists of plain containers
//! and counters that are consistent at every panic point (no
//! multi-step invariants span a panic), so for them the poison flag is
//! noise, not evidence: recover the guard and keep serving. PR 8
//! established the pattern for the serving `EventQueue`; this helper
//! extends it to the remaining `lock().unwrap()` sites so a single
//! panicking checkout can no longer take down every later caller.

use std::sync::{Mutex, MutexGuard, PoisonError};

/// Lock `m`, recovering the guard from a poisoned mutex. Use only for
/// state that is consistent at every panic point (see module docs).
pub fn lock_recover<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn recovers_a_poisoned_mutex() {
        let m = Arc::new(Mutex::new(7i32));
        let m2 = Arc::clone(&m);
        let _ = std::thread::spawn(move || {
            let _guard = m2.lock().unwrap();
            panic!("poison it");
        })
        .join();
        assert!(m.lock().is_err(), "mutex must actually be poisoned");
        assert_eq!(*lock_recover(&m), 7);
        *lock_recover(&m) = 8;
        assert_eq!(*lock_recover(&m), 8);
    }
}
