//! The `mediapipe` CLI: run graphs from pbtxt configs, validate them,
//! analyze and visualize traces, serve any registered graph (the
//! detector by default), list calculators.
//!
//! ```text
//! mediapipe run graphs/object_detection.pbtxt --trace /tmp/t.tsv
//! mediapipe validate graphs/face_landmark.pbtxt
//! mediapipe trace /tmp/t.tsv
//! mediapipe visualize /tmp/t.tsv -o /tmp/t.html
//! mediapipe serve --requests 1000 --max-batch 8 --streaming --pipeline-depth 4 \
//!     --dispatch-mode sharded
//! mediapipe serve --streaming --graph echo --swap-to echo_deep
//! mediapipe serve --streaming --graph pose_landmark
//! mediapipe serve --deadline-ms 50 --max-queue 256 --streaming --adaptive-depth 8
//! mediapipe serve --streaming --graph holistic_multi_model --worker 127.0.0.1:7071
//! mediapipe route --workers 127.0.0.1:7071,127.0.0.1:7072 --requests 1000
//! mediapipe list-calculators
//! ```
//!
//! `serve --graph <name>` serves any entry of the CLI's graph registry —
//! the staged echo pipelines plus the scenario catalog (`pose_landmark`,
//! `holistic_multi_model`, `detection_cascade`) — returning each graph's
//! typed payloads (landmarks, detections, named maps); `route` prints
//! the payload kinds it received back.

use std::sync::Arc;
use std::time::Duration;

use mediapipe::executor::DispatchMode;
use mediapipe::prelude::*;
use mediapipe::runtime::shared_engine;
use mediapipe::serving::pipeline::staged_pipeline_config;
use mediapipe::serving::{
    install_catalog, GraphRegistry, PipelineServer, Router, RouterConfig, ServerConfig,
    ServingMode, ServingPayload, WorkerServer,
};
use mediapipe::visualizer;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let code = match args.first().map(|s| s.as_str()) {
        Some("run") => cmd_run(&args[1..]),
        Some("validate") => cmd_validate(&args[1..]),
        Some("trace") => cmd_trace(&args[1..]),
        Some("visualize") => cmd_visualize(&args[1..]),
        Some("serve") => cmd_serve(&args[1..]),
        Some("route") => cmd_route(&args[1..]),
        Some("list-calculators") => cmd_list(),
        _ => {
            eprintln!(
                "usage: mediapipe <run|validate|trace|visualize|serve|route|list-calculators> ..."
            );
            2
        }
    };
    std::process::exit(code);
}

fn flag_value<'a>(args: &'a [String], name: &str) -> Option<&'a str> {
    args.iter()
        .position(|a| a == name)
        .and_then(|i| args.get(i + 1))
        .map(|s| s.as_str())
}

/// Provide standard side packets any graph may reference: the inference
/// engine (when artifacts are built) under the side-packet name
/// "engine".
fn standard_side_packets(config: &GraphConfig) -> MpResult<SidePackets> {
    let mut side = SidePackets::new();
    for sp in &config.input_side_packets {
        if sp.name == "engine" {
            let dir = std::env::var("MP_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
            let engine = shared_engine(&dir)?;
            side.insert("engine".into(), Packet::new(engine, Timestamp::UNSET));
        }
    }
    Ok(side)
}

fn cmd_run(args: &[String]) -> i32 {
    let Some(path) = args.first() else {
        eprintln!("usage: mediapipe run <graph.pbtxt> [--trace out.tsv] [--html out.html]");
        return 2;
    };
    let text = match std::fs::read_to_string(path) {
        Ok(t) => t,
        Err(e) => {
            eprintln!("read {path}: {e}");
            return 1;
        }
    };
    let run = || -> MpResult<()> {
        let mut config = GraphConfig::parse(&text)?;
        if args.iter().any(|a| a == "--trace" || a == "--html") && !config.profiler.enabled {
            config.profiler.enabled = true;
            config.profiler.buffer_size = 1 << 18;
        }
        let mut graph = Graph::new(&config)?;
        let side = standard_side_packets(&config)?;
        // Attach counters to every graph output.
        let mut counters = Vec::new();
        let outputs: Vec<String> = graph
            .plan()
            .graph_outputs
            .iter()
            .map(|(n, _)| n.clone())
            .collect();
        for name in outputs {
            let c = std::sync::Arc::new(std::sync::atomic::AtomicU64::new(0));
            let c2 = std::sync::Arc::clone(&c);
            graph.observe_output(&name, move |_p| {
                c2.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
            })?;
            counters.push((name, c));
        }
        let t0 = std::time::Instant::now();
        graph.start_run(side)?;
        graph.wait_until_done()?;
        let dt = t0.elapsed();
        println!("graph finished in {dt:?}");
        for (name, c) in counters {
            let n = c.load(std::sync::atomic::Ordering::Relaxed);
            println!(
                "output '{name}': {n} packets ({:.1}/s)",
                n as f64 / dt.as_secs_f64()
            );
        }
        if let Some(tp) = flag_value(args, "--trace") {
            let tf = TraceFile::capture(graph.tracer());
            tf.save_tsv(tp)?;
            println!("trace written to {tp} ({} events)", tf.events.len());
        }
        if let Some(hp) = flag_value(args, "--html") {
            let tf = TraceFile::capture(graph.tracer());
            visualizer::save_html(&tf, hp)?;
            println!("visualization written to {hp}");
        }
        Ok(())
    };
    match run() {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e}");
            1
        }
    }
}

fn cmd_validate(args: &[String]) -> i32 {
    let Some(path) = args.first() else {
        eprintln!("usage: mediapipe validate <graph.pbtxt>");
        return 2;
    };
    let run = || -> MpResult<()> {
        let text = std::fs::read_to_string(path)?;
        let config = GraphConfig::parse(&text)?;
        let expanded = mediapipe::graph::expand_subgraphs(
            &config,
            SubgraphRegistry::global(),
            CalculatorRegistry::global(),
        )?;
        let plan = mediapipe::graph::plan(&expanded, CalculatorRegistry::global())?;
        println!(
            "OK: {} nodes, {} streams",
            plan.nodes.len(),
            plan.streams.len()
        );
        for n in &plan.nodes {
            println!(
                "  [{}] {} (queue '{}', priority {}{})",
                n.config.name,
                n.config.calculator,
                plan.queue_names[n.queue],
                n.priority,
                if n.is_source { ", source" } else { "" }
            );
        }
        Ok(())
    };
    match run() {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("INVALID: {e}");
            1
        }
    }
}

fn cmd_trace(args: &[String]) -> i32 {
    let Some(path) = args.first() else {
        eprintln!("usage: mediapipe trace <trace.tsv>");
        return 2;
    };
    match TraceFile::load_tsv(path) {
        Ok(tf) => {
            let mut prof = mediapipe::tracer::profile::analyze(&tf);
            print!("{}", mediapipe::tracer::profile::report(&mut prof));
            0
        }
        Err(e) => {
            eprintln!("error: {e}");
            1
        }
    }
}

fn cmd_visualize(args: &[String]) -> i32 {
    let Some(path) = args.first() else {
        eprintln!("usage: mediapipe visualize <trace.tsv> [-o out.html]");
        return 2;
    };
    match TraceFile::load_tsv(path) {
        Ok(tf) => {
            print!("{}", visualizer::timeline_ascii(&tf, 100));
            print!("{}", visualizer::graph_ascii(&tf));
            if let Some(out) = flag_value(args, "-o") {
                if let Err(e) = visualizer::save_html(&tf, out) {
                    eprintln!("error: {e}");
                    return 1;
                }
                println!("wrote {out}");
            }
            0
        }
        Err(e) => {
            eprintln!("error: {e}");
            1
        }
    }
}

fn cmd_serve(args: &[String]) -> i32 {
    let requests: usize = flag_value(args, "--requests")
        .and_then(|v| v.parse().ok())
        .unwrap_or(500);
    let max_batch: usize = flag_value(args, "--max-batch")
        .and_then(|v| v.parse().ok())
        .unwrap_or(8);
    let clients: usize = flag_value(args, "--clients")
        .and_then(|v| v.parse().ok())
        .unwrap_or(4);
    // --streaming: long-lived sessions fed successive timestamps instead
    // of one pooled graph per batch (see rust/src/serving docs).
    let mode = if args.iter().any(|a| a == "--streaming") {
        ServingMode::Streaming
    } else {
        ServingMode::Pooled
    };
    // --pipeline-depth K: streaming batches kept in flight per session
    // before the batcher waits for the oldest (1 = submit-then-wait).
    let pipeline_depth: usize = flag_value(args, "--pipeline-depth")
        .and_then(|v| v.parse().ok())
        .unwrap_or(1);
    // --deadline-ms D: stamp every request with a completion deadline;
    // the server sheds work it estimates it cannot finish in time
    // (typed Overloaded) and expires queued jobs whose deadline passed
    // (typed DeadlineExceeded). Omit to disable deadline shedding.
    let request_deadline = flag_value(args, "--deadline-ms")
        .and_then(|v| v.parse::<u64>().ok())
        .map(Duration::from_millis);
    // --max-queue N: hard cap on the server's intake queue (0 =
    // unbounded); submissions beyond it are rejected immediately.
    let max_queue_depth: usize = flag_value(args, "--max-queue")
        .and_then(|v| v.parse().ok())
        .unwrap_or(1024);
    // --adaptive-depth MAX: let the streaming batcher grow/shrink the
    // pipeline window between 1 and MAX from the observed queue-vs-
    // residence imbalance instead of the fixed --pipeline-depth.
    let pipeline_depth_max: usize = flag_value(args, "--adaptive-depth")
        .and_then(|v| v.parse().ok())
        .unwrap_or(0);
    // --dispatch-mode: executor steal-dispatch engine for the server's
    // private pool — the sharded default or one of the ablations.
    let dispatch_mode = match flag_value(args, "--dispatch-mode") {
        None | Some("sharded") => DispatchMode::Sharded,
        Some("indexed") => DispatchMode::Indexed,
        Some("linear") => DispatchMode::LinearScan,
        Some(other) => {
            eprintln!("--dispatch-mode must be sharded|indexed|linear, got '{other}'");
            return 2;
        }
    };
    // --graph: serve a named entry from the CLI's graph registry instead
    // of the built-in detector pipeline. --swap-to: after half the
    // requests, blue-green hot-swap the served graph to the named
    // entry's config (see rust/src/serving "Graph registry & hot-swap").
    let graph = flag_value(args, "--graph").map(str::to_string);
    let swap_to = flag_value(args, "--swap-to").map(str::to_string);
    let run = || -> MpResult<()> {
        // The CLI registry offers two staged echo pipelines (they speak
        // the serving frames/detections interface without needing model
        // artifacts) plus the scenario catalog (pose_landmark,
        // holistic_multi_model, detection_cascade — per-frame typed
        // payloads), so registry serving, typed payloads and swaps can
        // all be exercised from the command line.
        let registry = if graph.is_some() || swap_to.is_some() {
            let reg = Arc::new(GraphRegistry::new());
            reg.register("echo", &staged_pipeline_config(&[100, 200, 100], Some(16))?)?;
            reg.register(
                "echo_deep",
                &staged_pipeline_config(&[100, 200, 400, 200, 100], Some(16))?,
            )?;
            install_catalog(&reg)?;
            if let Some(g) = &graph {
                if !reg.contains(g) {
                    return Err(MpError::Validation(format!(
                        "--graph '{g}' is not registered (known: {:?})",
                        reg.names()
                    )));
                }
            }
            if let Some(t) = &swap_to {
                if !reg.contains(t) {
                    return Err(MpError::Validation(format!(
                        "--swap-to '{t}' is not registered (known: {:?})",
                        reg.names()
                    )));
                }
            }
            Some(reg)
        } else {
            None
        };
        let server = PipelineServer::start(ServerConfig {
            artifact_dir: std::env::var("MP_ARTIFACTS").unwrap_or_else(|_| "artifacts".into()),
            max_batch,
            max_wait: Duration::from_millis(2),
            mode,
            pipeline_depth,
            request_deadline,
            max_queue_depth,
            pipeline_depth_max,
            dispatch_mode,
            graph_name: graph.clone(),
            registry: registry.clone(),
            ..Default::default()
        })?;
        {
            let d = server.descriptor();
            let outs: Vec<String> = d
                .outputs
                .iter()
                .map(|(name, kind)| format!("{name}:{}", kind.name()))
                .collect();
            println!(
                "serving '{}': {} ({}) -> {}",
                server.graph_name(),
                d.input_stream,
                d.input_kind.name(),
                outs.join(", ")
            );
        }
        // --worker ADDR: instead of self-driving synthetic load, expose
        // this server over a socket for a front-end router (see
        // rust/src/serving "Distributed serving") and serve until
        // killed.
        if let Some(addr) = flag_value(args, "--worker") {
            let worker = WorkerServer::start(addr, server)?;
            println!("worker serving on {}", worker.local_addr());
            loop {
                std::thread::sleep(Duration::from_secs(3600));
            }
        }
        // Each wave submits rendered frames as typed payloads; the
        // handle adapts a frame to the detector's tensor input, and
        // catalog graphs consume it directly.
        let run_wave = |n: usize, seed: u64| {
            let mut handles = Vec::new();
            for c in 0..clients {
                let h = server.handle();
                let per = n / clients.max(1);
                handles.push(std::thread::spawn(move || {
                    let mut world =
                        mediapipe::perception::SyntheticWorld::new(32, 32, 2, seed + c as u64)
                            .with_object_sizes(0.12, 0.2);
                    for _ in 0..per {
                        world.step();
                        let frame = world.render();
                        let rx = h.submit_payload(ServingPayload::Frame(frame));
                        let _ = rx.recv();
                    }
                }));
            }
            for h in handles {
                let _ = h.join();
            }
        };
        let t0 = std::time::Instant::now();
        if let Some(target) = &swap_to {
            run_wave(requests / 2, 100);
            let reg = registry.as_ref().expect("registry exists when --swap-to is set");
            let version = server.swap_graph(reg.get(target)?.config())?;
            println!(
                "swapped '{}' to the '{target}' config (now version {version})",
                server.graph_name()
            );
            run_wave(requests - requests / 2, 200);
        } else {
            run_wave(requests, 100);
        }
        let dt = t0.elapsed();
        println!("{}", server.metrics().report());
        println!(
            "throughput: {:.1} req/s over {dt:?}",
            server.metrics().requests.get() as f64 / dt.as_secs_f64()
        );
        Ok(())
    };
    match run() {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e}");
            1
        }
    }
}

/// `mediapipe route --workers a,b,c`: front a pool of `serve --worker`
/// processes with the session-sharding router and drive synthetic
/// streaming load through it (see rust/src/serving "Distributed
/// serving").
fn cmd_route(args: &[String]) -> i32 {
    let Some(list) = flag_value(args, "--workers") else {
        eprintln!(
            "usage: mediapipe route --workers host:port[,host:port...] \
             [--requests N] [--sessions S] [--deadline-ms D]"
        );
        return 2;
    };
    let workers: Vec<String> = list
        .split(',')
        .map(|s| s.trim().to_string())
        .filter(|s| !s.is_empty())
        .collect();
    let requests: usize = flag_value(args, "--requests")
        .and_then(|v| v.parse().ok())
        .unwrap_or(500);
    let sessions: u64 = flag_value(args, "--sessions")
        .and_then(|v| v.parse().ok())
        .unwrap_or(4)
        .max(1);
    let request_deadline = flag_value(args, "--deadline-ms")
        .and_then(|v| v.parse::<u64>().ok())
        .map(Duration::from_millis);
    let run = || -> MpResult<()> {
        let mut cfg = RouterConfig::new(workers);
        cfg.request_deadline = request_deadline;
        let router = Router::start(cfg)?;
        let mut world = mediapipe::perception::SyntheticWorld::new(32, 32, 2, 7)
            .with_object_sizes(0.12, 0.2);
        let mut inflight = std::collections::VecDeque::new();
        let (mut ok, mut failed) = (0u64, 0u64);
        // Tally the reply payload kinds so the run's output shows what
        // the served graph actually returned (detections for the
        // detector/echo pipelines, landmarks or maps for the catalog).
        let mut kinds: std::collections::BTreeMap<&'static str, u64> =
            std::collections::BTreeMap::new();
        let mut settle = |rx: std::sync::mpsc::Receiver<MpResult<ServingPayload>>| {
            match rx.recv_timeout(Duration::from_secs(30)) {
                Ok(Ok(p)) => {
                    ok += 1;
                    *kinds.entry(p.kind().name()).or_insert(0) += 1;
                }
                _ => failed += 1,
            }
        };
        let t0 = std::time::Instant::now();
        for i in 0..requests {
            world.step();
            let frame = world.render();
            inflight.push_back(
                router.submit_payload(i as u64 % sessions, ServingPayload::Frame(frame)),
            );
            // Keep a bounded window in flight so a slow worker applies
            // backpressure here instead of flooding its intake queue.
            if inflight.len() >= 64 {
                settle(inflight.pop_front().expect("non-empty window"));
            }
        }
        for rx in inflight {
            settle(rx);
        }
        let dt = t0.elapsed();
        println!("{ok} ok / {failed} failed over {dt:?}");
        for (kind, count) in &kinds {
            println!("  payload {kind:<11} {count}");
        }
        println!("{}", router.report());
        Ok(())
    };
    match run() {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e}");
            1
        }
    }
}

fn cmd_list() -> i32 {
    for name in CalculatorRegistry::global().names() {
        println!("{name}");
    }
    0
}
