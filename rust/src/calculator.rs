//! Calculators: the nodes of a MediaPipe graph (§3.4).
//!
//! Every node derives from the same base and comprises four essential
//! methods: `GetContract()`, `Open()`, `Process()` and `Close()`. In this
//! rust port, `GetContract` lives on the [`crate::registry::CalculatorFactory`]
//! (it is a *static* method in C++ MediaPipe), while `open/process/close`
//! are methods of the [`Calculator`] trait, invoked by the framework with
//! a [`CalculatorContext`].

use std::collections::BTreeMap;

use crate::error::{MpError, MpResult};
use crate::packet::{Packet, PacketType};
use crate::timestamp::{Timestamp, TimestampBound};

/// Which input policy a node uses (§4.1.3). Most nodes use
/// [`InputPolicyKind::Default`]; a calculator that needs another policy
/// must declare it in its contract (footnote 3 of the paper).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum InputPolicyKind {
    /// Deterministic synchronization: input sets are formed from settled
    /// timestamps, processed in strictly ascending order, nothing
    /// dropped.
    Default,
    /// Receive every packet as soon as it arrives, sacrificing the
    /// cross-stream alignment guarantees. Used by flow-control nodes
    /// that must make fast decisions (§4.1.4).
    Immediate,
    /// Timestamp alignment enforced *within* declared sets of inputs but
    /// not across sets (§4.1.3 last paragraph).
    SyncSets,
}

/// One stream port (input or output) declared by a contract.
#[derive(Clone, Debug)]
pub struct PortSpec {
    /// Tag, e.g. "FRAME"; empty for untagged (index-addressed) ports.
    pub tag: String,
    /// Declared packet type; checked at graph initialization.
    pub packet_type: PacketType,
    /// Optional ports may be left unconnected in the config.
    pub optional: bool,
}

/// One side-packet port declared by a contract (§3.3).
#[derive(Clone, Debug)]
pub struct SidePortSpec {
    pub tag: String,
    pub packet_type: PacketType,
    pub optional: bool,
}

/// The calculator's declared interface, verified against the graph
/// config when the graph is initialized (§3.4 GetContract, §3.5 check 3).
#[derive(Clone, Debug)]
pub struct Contract {
    pub inputs: Vec<PortSpec>,
    pub outputs: Vec<PortSpec>,
    pub input_side: Vec<SidePortSpec>,
    pub output_side: Vec<SidePortSpec>,
    pub policy: InputPolicyKind,
    /// `Some(k)`: producing output at input-ts + k is guaranteed, so the
    /// framework auto-propagates output bounds from input bounds. `None`:
    /// the calculator manages bounds itself (or simply delays settling).
    pub timestamp_offset: Option<i64>,
    /// For `SyncSets`: port indices grouped into independently
    /// synchronized sets.
    pub sync_sets: Vec<Vec<usize>>,
    /// Advanced (§3 footnote 1): max simultaneous Process() invocations,
    /// assuming temporal independence. Default 1.
    pub max_in_flight: usize,
}

impl Contract {
    pub fn new() -> Contract {
        Contract {
            inputs: Vec::new(),
            outputs: Vec::new(),
            input_side: Vec::new(),
            output_side: Vec::new(),
            policy: InputPolicyKind::Default,
            timestamp_offset: None,
            sync_sets: Vec::new(),
            max_in_flight: 1,
        }
    }

    /// Declare one input stream port.
    pub fn input(mut self, tag: &str, ty: PacketType) -> Self {
        self.inputs.push(PortSpec {
            tag: tag.to_string(),
            packet_type: ty,
            optional: false,
        });
        self
    }

    /// Declare `n` input ports sharing a tag (addressed TAG:0 .. TAG:n-1).
    pub fn input_repeated(mut self, tag: &str, ty: PacketType, n: usize) -> Self {
        for _ in 0..n {
            self.inputs.push(PortSpec {
                tag: tag.to_string(),
                packet_type: ty,
                optional: false,
            });
        }
        self
    }

    pub fn optional_input(mut self, tag: &str, ty: PacketType) -> Self {
        self.inputs.push(PortSpec {
            tag: tag.to_string(),
            packet_type: ty,
            optional: true,
        });
        self
    }

    /// Declare one output stream port.
    pub fn output(mut self, tag: &str, ty: PacketType) -> Self {
        self.outputs.push(PortSpec {
            tag: tag.to_string(),
            packet_type: ty,
            optional: false,
        });
        self
    }

    pub fn output_repeated(mut self, tag: &str, ty: PacketType, n: usize) -> Self {
        for _ in 0..n {
            self.outputs.push(PortSpec {
                tag: tag.to_string(),
                packet_type: ty,
                optional: false,
            });
        }
        self
    }

    pub fn optional_output(mut self, tag: &str, ty: PacketType) -> Self {
        self.outputs.push(PortSpec {
            tag: tag.to_string(),
            packet_type: ty,
            optional: true,
        });
        self
    }

    /// Declare one input side packet (§3.3).
    pub fn side_input(mut self, tag: &str, ty: PacketType) -> Self {
        self.input_side.push(SidePortSpec {
            tag: tag.to_string(),
            packet_type: ty,
            optional: false,
        });
        self
    }

    pub fn optional_side_input(mut self, tag: &str, ty: PacketType) -> Self {
        self.input_side.push(SidePortSpec {
            tag: tag.to_string(),
            packet_type: ty,
            optional: true,
        });
        self
    }

    /// Declare one output side packet.
    pub fn side_output(mut self, tag: &str, ty: PacketType) -> Self {
        self.output_side.push(SidePortSpec {
            tag: tag.to_string(),
            packet_type: ty,
            optional: false,
        });
        self
    }

    /// Select a non-default input policy (must be declared here, §4.1.3
    /// footnote: calculators written for a special policy declare it in
    /// their contract).
    pub fn with_policy(mut self, p: InputPolicyKind) -> Self {
        self.policy = p;
        self
    }

    /// Group input ports into independently synchronized sets (implies
    /// the SyncSets policy).
    pub fn with_sync_sets(mut self, sets: Vec<Vec<usize>>) -> Self {
        self.policy = InputPolicyKind::SyncSets;
        self.sync_sets = sets;
        self
    }

    /// Declare the timestamp offset for automatic bound propagation.
    pub fn with_timestamp_offset(mut self, k: i64) -> Self {
        self.timestamp_offset = Some(k);
        self
    }

    /// Allow up to `n` parallel Process() calls (§3 footnote 1).
    pub fn with_max_in_flight(mut self, n: usize) -> Self {
        self.max_in_flight = n.max(1);
        self
    }

    /// Index of the first input port with `tag`, plus port count.
    pub fn find_input(&self, tag: &str) -> Option<usize> {
        self.inputs.iter().position(|p| p.tag == tag)
    }

    pub fn find_output(&self, tag: &str) -> Option<usize> {
        self.outputs.iter().position(|p| p.tag == tag)
    }

    pub fn find_side_input(&self, tag: &str) -> Option<usize> {
        self.input_side.iter().position(|p| p.tag == tag)
    }
}

impl Default for Contract {
    fn default() -> Self {
        Contract::new()
    }
}

/// What `Process()` tells the framework (§3.4/§3.5). Sources signal the
/// end of their data with [`ProcessOutcome::Stop`]; the framework then
/// closes the node ("source calculators indicate that they have finished
/// sending packets").
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ProcessOutcome {
    /// Keep the node alive.
    Continue,
    /// The node is done producing; close it and mark outputs Done.
    Stop,
}

/// Buffered output mutations collected during one `open/process/close`
/// call, flushed by the scheduler after the call returns. Buffering keeps
/// all stream mutation on the scheduler's side, so calculator code never
/// touches shared state.
#[derive(Debug, Default)]
pub struct OutputPortBuffer {
    pub packets: Vec<Packet>,
    /// Explicit bound update (§4.1.2 footnote 6: a producer may advance
    /// the bound farther than the last packet implies).
    pub next_bound: Option<TimestampBound>,
    /// Close this output stream.
    pub close: bool,
}

/// The per-invocation view a calculator gets of its node (§3.4).
pub struct CalculatorContext<'a> {
    pub(crate) node_name: &'a str,
    /// Timestamp of the current input set (UNSTARTED in Open/Close).
    pub(crate) input_timestamp: Timestamp,
    /// One slot per contract input port; `Packet::empty()` if the port
    /// has no packet at this timestamp (paper footnote 7).
    pub(crate) inputs: &'a [Packet],
    /// Current bound of each input stream (advanced policies, limiters).
    pub(crate) input_bounds: &'a [TimestampBound],
    pub(crate) outputs: &'a mut [OutputPortBuffer],
    /// One slot per contract side-input port.
    pub(crate) side_inputs: &'a [Packet],
    /// Side outputs (set once, at Open or Close).
    pub(crate) side_outputs: &'a mut [Packet],
    pub(crate) contract: &'a Contract,
    /// Filled by the serving layer / options at graph build.
    pub(crate) options: &'a Options,
}

impl<'a> CalculatorContext<'a> {
    /// Name of this node instance in the graph.
    pub fn node_name(&self) -> &str {
        self.node_name
    }

    /// Timestamp of the current input set.
    pub fn input_timestamp(&self) -> Timestamp {
        self.input_timestamp
    }

    /// Packet on input port `i` (may be empty — footnote 7).
    pub fn input(&self, i: usize) -> &Packet {
        &self.inputs[i]
    }

    /// Packet on the first input port tagged `tag`.
    pub fn input_tag(&self, tag: &str) -> MpResult<&Packet> {
        let i = self
            .contract
            .find_input(tag)
            .ok_or_else(|| MpError::internal(format!("no input tag {tag}")))?;
        Ok(&self.inputs[i])
    }

    /// Number of input ports.
    pub fn input_count(&self) -> usize {
        self.inputs.len()
    }

    /// Current timestamp bound of input stream `i`.
    pub fn input_bound(&self, i: usize) -> TimestampBound {
        self.input_bounds[i]
    }

    /// Emit `packet` on output port `i`.
    pub fn output(&mut self, i: usize, packet: Packet) {
        self.outputs[i].packets.push(packet);
    }

    /// Emit a value on output port `i` at the current input timestamp.
    /// Footnote 5: outputting at the input timestamp inherently obeys the
    /// monotonicity requirement.
    pub fn output_now<T: Send + Sync + 'static>(&mut self, i: usize, value: T) {
        let ts = self.input_timestamp;
        self.outputs[i].packets.push(Packet::new(value, ts));
    }

    /// Emit on the first output port tagged `tag`.
    pub fn output_tag(&mut self, tag: &str, packet: Packet) -> MpResult<()> {
        let i = self
            .contract
            .find_output(tag)
            .ok_or_else(|| MpError::internal(format!("no output tag {tag}")))?;
        self.outputs[i].packets.push(packet);
        Ok(())
    }

    /// Number of output ports.
    pub fn output_count(&self) -> usize {
        self.outputs.len()
    }

    /// Explicitly advance the bound of output `i` (footnote 6: provide a
    /// tighter bound so downstream settles sooner).
    pub fn set_next_timestamp_bound(&mut self, i: usize, bound: TimestampBound) {
        self.outputs[i].next_bound = Some(bound);
    }

    /// Close output stream `i`: no more packets will be sent on it.
    pub fn close_output(&mut self, i: usize) {
        self.outputs[i].close = true;
    }

    /// Side packet on side-input port `i`.
    pub fn side_input(&self, i: usize) -> &Packet {
        &self.side_inputs[i]
    }

    /// Side packet on the first side-input port tagged `tag`.
    pub fn side_input_tag(&self, tag: &str) -> MpResult<&Packet> {
        let i = self
            .contract
            .find_side_input(tag)
            .ok_or_else(|| MpError::MissingSidePacket(tag.to_string()))?;
        Ok(&self.side_inputs[i])
    }

    /// Set side output `i` (valid in Open or Close).
    pub fn set_side_output(&mut self, i: usize, packet: Packet) {
        self.side_outputs[i] = packet;
    }

    /// Node options from the GraphConfig (§3.6 node-specific options).
    pub fn options(&self) -> &Options {
        self.options
    }
}

/// Node-specific options from the GraphConfig (§3.6). MediaPipe uses
/// per-calculator protos; we use a typed key-value map with the same
/// role.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Options {
    map: BTreeMap<String, OptionValue>,
}

/// A single option value.
#[derive(Clone, Debug, PartialEq)]
pub enum OptionValue {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    IntList(Vec<i64>),
    FloatList(Vec<f64>),
    StrList(Vec<String>),
}

impl Options {
    pub fn new() -> Options {
        Options::default()
    }

    pub fn set(&mut self, key: &str, v: OptionValue) -> &mut Self {
        self.map.insert(key.to_string(), v);
        self
    }

    pub fn with(mut self, key: &str, v: OptionValue) -> Self {
        self.map.insert(key.to_string(), v);
        self
    }

    pub fn get(&self, key: &str) -> Option<&OptionValue> {
        self.map.get(key)
    }

    pub fn get_str(&self, key: &str) -> Option<&str> {
        match self.map.get(key) {
            Some(OptionValue::Str(s)) => Some(s),
            _ => None,
        }
    }

    pub fn get_int(&self, key: &str) -> Option<i64> {
        match self.map.get(key) {
            Some(OptionValue::Int(v)) => Some(*v),
            Some(OptionValue::Float(v)) if v.fract() == 0.0 => Some(*v as i64),
            _ => None,
        }
    }

    pub fn get_float(&self, key: &str) -> Option<f64> {
        match self.map.get(key) {
            Some(OptionValue::Float(v)) => Some(*v),
            Some(OptionValue::Int(v)) => Some(*v as f64),
            _ => None,
        }
    }

    pub fn get_bool(&self, key: &str) -> Option<bool> {
        match self.map.get(key) {
            Some(OptionValue::Bool(v)) => Some(*v),
            _ => None,
        }
    }

    pub fn get_int_list(&self, key: &str) -> Option<&[i64]> {
        match self.map.get(key) {
            Some(OptionValue::IntList(v)) => Some(v),
            _ => None,
        }
    }

    pub fn int_or(&self, key: &str, default: i64) -> i64 {
        self.get_int(key).unwrap_or(default)
    }

    pub fn float_or(&self, key: &str, default: f64) -> f64 {
        self.get_float(key).unwrap_or(default)
    }

    pub fn bool_or(&self, key: &str, default: bool) -> bool {
        self.get_bool(key).unwrap_or(default)
    }

    pub fn str_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get_str(key).unwrap_or(default)
    }

    pub fn iter(&self) -> impl Iterator<Item = (&String, &OptionValue)> {
        self.map.iter()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }
}

/// The calculator behaviour trait (§3.4). `open` is called once after the
/// graph starts (side packets available; may emit packets); `process` is
/// called whenever the node's input policy forms an input set (or, for
/// sources, whenever the node is scheduled); `close` is always called if
/// `open` succeeded — even if the run is terminating due to an error.
pub trait Calculator: Send {
    /// Prepare per-graph-run state; may emit packets.
    fn open(&mut self, _ctx: &mut CalculatorContext) -> MpResult<()> {
        Ok(())
    }

    /// Handle one input set (or produce spontaneously, for sources).
    fn process(&mut self, ctx: &mut CalculatorContext) -> MpResult<ProcessOutcome>;

    /// Tear down; may emit final packets (paper footnote 2: a media
    /// decoder flushing frames buffered in its encoding state).
    fn close(&mut self, _ctx: &mut CalculatorContext) -> MpResult<()> {
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn contract_builder_and_lookup() {
        let c = Contract::new()
            .input("FRAME", PacketType::Any)
            .input("DETECTIONS", PacketType::of::<Vec<u8>>())
            .output("OUT", PacketType::Any)
            .side_input("MODEL", PacketType::of::<String>())
            .with_timestamp_offset(0);
        assert_eq!(c.find_input("DETECTIONS"), Some(1));
        assert_eq!(c.find_input("NOPE"), None);
        assert_eq!(c.find_output("OUT"), Some(0));
        assert_eq!(c.find_side_input("MODEL"), Some(0));
        assert_eq!(c.timestamp_offset, Some(0));
        assert_eq!(c.policy, InputPolicyKind::Default);
    }

    #[test]
    fn repeated_ports_share_tag() {
        let c = Contract::new().input_repeated("IN", PacketType::Any, 3);
        assert_eq!(c.inputs.len(), 3);
        assert!(c.inputs.iter().all(|p| p.tag == "IN"));
        // find_input returns the first.
        assert_eq!(c.find_input("IN"), Some(0));
    }

    #[test]
    fn sync_sets_sets_policy() {
        let c = Contract::new()
            .input_repeated("A", PacketType::Any, 2)
            .input("B", PacketType::Any)
            .with_sync_sets(vec![vec![0, 1], vec![2]]);
        assert_eq!(c.policy, InputPolicyKind::SyncSets);
        assert_eq!(c.sync_sets.len(), 2);
    }

    #[test]
    fn options_typed_access() {
        let mut o = Options::new();
        o.set("n", OptionValue::Int(4));
        o.set("rate", OptionValue::Float(0.5));
        o.set("name", OptionValue::Str("det".into()));
        o.set("on", OptionValue::Bool(true));
        assert_eq!(o.get_int("n"), Some(4));
        assert_eq!(o.get_float("rate"), Some(0.5));
        // int/float coercion both ways
        assert_eq!(o.get_float("n"), Some(4.0));
        assert_eq!(o.get_str("name"), Some("det"));
        assert_eq!(o.get_bool("on"), Some(true));
        assert_eq!(o.int_or("missing", 7), 7);
    }

    #[test]
    fn max_in_flight_clamped_to_one() {
        let c = Contract::new().with_max_in_flight(0);
        assert_eq!(c.max_in_flight, 1);
    }
}
