//! Tracking calculators (§6.1): the lightweight tracker that propagates
//! detections to every frame while the detector runs on a sub-sampled
//! stream, and the detection-merging node that reconciles fresh
//! detections with tracked ones.

use std::collections::HashMap;

use crate::calculator::{Calculator, CalculatorContext, Contract, ProcessOutcome};
use crate::error::MpResult;
use crate::packet::PacketType;
use crate::perception::types::{iou, Detection, Detections, Rect};
use crate::perception::ImageFrame;
use crate::registry::CalculatorRegistry;

/// One tracked target: constant-velocity motion model updated by
/// appearance (brightness-centroid) correlation against each new frame.
#[derive(Clone, Debug)]
struct Track {
    id: u64,
    rect: Rect,
    vx: f32,
    vy: f32,
    class_id: u32,
    score: f32,
    /// Frames since the last detector confirmation.
    age: u32,
}

/// §6.1 BoxTracker: "the tracking branch updates earlier detections and
/// advances their locations to the current camera frame."
///
/// Inputs: FRAME (every frame), DETECTIONS (sparse, from the merger's
/// loopback — initializes/confirms tracks). Output: tracked detections
/// on every frame. Uses sync sets so frames are not blocked by the
/// sparse detection stream (the parallel-branches property of Fig. 1).
///
/// Options: `max_age` — drop tracks unconfirmed for this many frames
/// (default 30), `search` — local search radius in normalized units for
/// appearance correlation (default 0.05).
pub struct BoxTracker {
    tracks: Vec<Track>,
    next_id: u64,
    max_age: u32,
    search: f32,
    match_iou: f32,
    prev_frame: Option<ImageFrame>,
}

impl BoxTracker {
    /// Refine a predicted rect by local appearance search (the inline
    /// copy in `process` is the hot path; this method is the documented
    /// reference version, exercised by unit tests).
    #[cfg_attr(not(test), allow(dead_code))]
    /// Refine a predicted rect by local appearance search: among shifted
    /// candidates pick the brightest-interior one (our synthetic objects
    /// are bright boxes; a real impl would correlate patches).
    fn refine(&self, frame: &ImageFrame, rect: &Rect) -> Rect {
        // Candidate order matters: the UNSHIFTED position comes first and
        // wins ties (strict improvement required to move). Without this,
        // an object larger than the search step produces a plateau of
        // equal scores and the arbitrary first candidate causes a
        // constant directional drift.
        let mut best = rect.clamped();
        let mut best_score = frame.cropped(&best).mean();
        for (dx, dy) in [
            (0.0f32, -1.0f32), (0.0, 1.0), (-1.0, 0.0), (1.0, 0.0),
            (-1.0, -1.0), (1.0, -1.0), (-1.0, 1.0), (1.0, 1.0),
        ] {
            let cand = rect
                .translated(dx * self.search, dy * self.search)
                .clamped();
            let score = frame.cropped(&cand).mean();
            if score > best_score {
                best_score = score;
                best = cand;
            }
        }
        best
    }
}

impl Calculator for BoxTracker {
    fn open(&mut self, ctx: &mut CalculatorContext) -> MpResult<()> {
        let o = ctx.options();
        self.max_age = o.int_or("max_age", 30) as u32;
        self.search = o.float_or("search", 0.05) as f32;
        self.match_iou = o.float_or("match_iou", 0.1) as f32;
        Ok(())
    }

    fn process(&mut self, ctx: &mut CalculatorContext) -> MpResult<ProcessOutcome> {
        // Sparse detections (when present) confirm/initialize tracks.
        // The loopback carries merged detections (§6.1: "sends merged
        // detections back to the tracker to initialize new tracking
        // targets if needed"). Detections carrying a track_id are this
        // tracker's own, possibly stale snapshots: they *refresh* their
        // track by id (never spawn — spawning from stale self-snapshots
        // is a positive feedback loop that explodes the track list).
        // Only id-less (fresh detector) detections may create tracks.
        let det_in = ctx.input(1);
        if !det_in.is_empty() {
            let dets = det_in.get::<Detections>()?.clone();
            for d in dets {
                if d.track_id.is_some() {
                    // Our own snapshot coming back around the loop: not a
                    // confirmation (only the detector confirms) — ignore,
                    // so unconfirmed tracks still expire via max_age.
                    continue;
                }
                // fresh detection: match to an existing track by IoU
                let mut best: Option<(usize, f32)> = None;
                for (i, t) in self.tracks.iter().enumerate() {
                    let v = iou(&t.rect, &d.bbox);
                    if v > self.match_iou {
                        best = match best {
                            Some((_, bv)) if bv >= v => best,
                            _ => Some((i, v)),
                        };
                    }
                }
                match best {
                    Some((i, _)) => {
                        let t = &mut self.tracks[i];
                        // velocity from confirmed displacement
                        t.vx = 0.5 * t.vx + 0.5 * (d.bbox.x - t.rect.x);
                        t.vy = 0.5 * t.vy + 0.5 * (d.bbox.y - t.rect.y);
                        t.rect = d.bbox;
                        t.score = d.score;
                        t.class_id = d.class_id;
                        t.age = 0;
                    }
                    None => {
                        self.tracks.push(Track {
                            id: self.next_id,
                            rect: d.bbox,
                            vx: 0.0,
                            vy: 0.0,
                            class_id: d.class_id,
                            score: d.score,
                            age: 0,
                        });
                        self.next_id += 1;
                    }
                }
            }
            // Safety net: merge tracks that converged onto the same
            // object (keep the older id — stable identities).
            let mut i = 0;
            while i < self.tracks.len() {
                let mut j = i + 1;
                while j < self.tracks.len() {
                    if self.tracks[i].class_id == self.tracks[j].class_id
                        && iou(&self.tracks[i].rect, &self.tracks[j].rect) > 0.5
                    {
                        self.tracks.remove(j);
                    } else {
                        j += 1;
                    }
                }
                i += 1;
            }
        }

        // Per-frame advance (the fast branch).
        let frame_in = ctx.input(0);
        if !frame_in.is_empty() {
            let frame = frame_in.get::<ImageFrame>()?;
            let search = self.search;
            let max_age = self.max_age;
            let mut refined: Vec<Rect> = Vec::with_capacity(self.tracks.len());
            for t in &self.tracks {
                let predicted = t.rect.translated(t.vx, t.vy).clamped();
                let r = {
                    // inline refine (same tie-breaking as Self::refine:
                    // unshifted candidate first, strict improvement to move)
                    let mut best = predicted;
                    let mut best_score = frame.cropped(&best).mean();
                    for (dx, dy) in [
                        (0.0f32, -1.0f32), (0.0, 1.0), (-1.0, 0.0), (1.0, 0.0),
                        (-1.0, -1.0), (1.0, -1.0), (-1.0, 1.0), (1.0, 1.0),
                    ] {
                        let cand = predicted.translated(dx * search, dy * search).clamped();
                        let score = frame.cropped(&cand).mean();
                        if score > best_score {
                            best_score = score;
                            best = cand;
                        }
                    }
                    best
                };
                refined.push(r);
            }
            for (t, r) in self.tracks.iter_mut().zip(refined) {
                t.vx = 0.7 * t.vx + 0.3 * (r.x - t.rect.x);
                t.vy = 0.7 * t.vy + 0.3 * (r.y - t.rect.y);
                t.rect = r;
                t.age += 1;
            }
            self.tracks.retain(|t| t.age <= max_age);
            self.prev_frame = Some(frame.clone());

            let out: Detections = self
                .tracks
                .iter()
                .map(|t| Detection {
                    bbox: t.rect,
                    score: t.score * 0.99f32.powi(t.age as i32),
                    class_id: t.class_id,
                    track_id: Some(t.id),
                })
                .collect();
            ctx.output(0, crate::packet::Packet::new(out, frame_in.timestamp()));
        }
        Ok(ProcessOutcome::Continue)
    }
}

/// §6.1 detection merging: "compares results and merges them with
/// detections from earlier frames removing duplicate results based on
/// their location in the frame and/or class proximity." Operates on the
/// same timestamp as the fresh detections (default input policy aligns
/// the two streams — exactly the property the paper calls out).
///
/// Inputs: DETECTIONS (fresh, sparse), TRACKED (from the tracker, dense
/// — only the set at matching timestamps is merged). Output: merged
/// detections (also fed back to the tracker in Fig. 1).
pub struct TrackedDetectionMerger {
    iou_thr: f32,
}

impl Calculator for TrackedDetectionMerger {
    fn open(&mut self, ctx: &mut CalculatorContext) -> MpResult<()> {
        self.iou_thr = ctx.options().float_or("iou_threshold", 0.4) as f32;
        Ok(())
    }

    fn process(&mut self, ctx: &mut CalculatorContext) -> MpResult<ProcessOutcome> {
        let fresh_in = ctx.input(0);
        let tracked_in = ctx.input(1);
        if fresh_in.is_empty() {
            return Ok(ProcessOutcome::Continue);
        }
        let mut merged: Detections = fresh_in.get::<Detections>()?.clone();
        if !tracked_in.is_empty() {
            for t in tracked_in.get::<Detections>()? {
                let dup = merged
                    .iter()
                    .any(|m| m.class_id == t.class_id && iou(&m.bbox, &t.bbox) > self.iou_thr);
                if !dup {
                    merged.push(t.clone());
                }
            }
        }
        ctx.output_now(0, merged);
        Ok(ProcessOutcome::Continue)
    }
}

/// Quality metric node: matches detections against ground truth and
/// accumulates precision/recall (used by the Fig. 1 bench and the
/// detector-swap example).
pub struct DetectionQuality {
    iou_thr: f32,
    pub stats: QualityStats,
    sink: Option<SharedQuality>,
}

/// Aggregated matching counts.
#[derive(Clone, Copy, Debug, Default)]
pub struct QualityStats {
    pub true_pos: u64,
    pub false_pos: u64,
    pub false_neg: u64,
    pub frames: u64,
}

impl QualityStats {
    pub fn precision(&self) -> f64 {
        let d = self.true_pos + self.false_pos;
        if d == 0 {
            0.0
        } else {
            self.true_pos as f64 / d as f64
        }
    }

    pub fn recall(&self) -> f64 {
        let d = self.true_pos + self.false_neg;
        if d == 0 {
            0.0
        } else {
            self.true_pos as f64 / d as f64
        }
    }
}

/// Shared stats payload (side packet).
pub type SharedQuality = std::sync::Arc<std::sync::Mutex<QualityStats>>;

impl Calculator for DetectionQuality {
    fn open(&mut self, ctx: &mut CalculatorContext) -> MpResult<()> {
        self.iou_thr = ctx.options().float_or("iou_threshold", 0.3) as f32;
        self.sink = Some(ctx.side_input(0).get::<SharedQuality>()?.clone());
        Ok(())
    }

    fn process(&mut self, ctx: &mut CalculatorContext) -> MpResult<ProcessOutcome> {
        let dets_in = ctx.input(0);
        let gt_in = ctx.input(1);
        if dets_in.is_empty() || gt_in.is_empty() {
            return Ok(ProcessOutcome::Continue);
        }
        let dets = dets_in.get::<Detections>()?;
        let gts = gt_in.get::<Detections>()?;
        let mut matched_gt = vec![false; gts.len()];
        let mut tp = 0u64;
        let mut fp = 0u64;
        for d in dets {
            let mut hit = false;
            for (i, g) in gts.iter().enumerate() {
                if !matched_gt[i] && iou(&d.bbox, &g.bbox) > self.iou_thr {
                    matched_gt[i] = true;
                    hit = true;
                    break;
                }
            }
            if hit {
                tp += 1;
            } else {
                fp += 1;
            }
        }
        let fne = matched_gt.iter().filter(|&&m| !m).count() as u64;
        let mut s = self.sink.as_ref().unwrap().lock().unwrap();
        s.true_pos += tp;
        s.false_pos += fp;
        s.false_neg += fne;
        s.frames += 1;
        Ok(ProcessOutcome::Continue)
    }
}

/// Simple per-track latency probe: emits (track count) so benches can
/// observe tracker liveness without depending on payload internals.
pub struct DetectionCounter;

impl Calculator for DetectionCounter {
    fn process(&mut self, ctx: &mut CalculatorContext) -> MpResult<ProcessOutcome> {
        let p = ctx.input(0);
        if !p.is_empty() {
            let n = p.get::<Detections>()?.len() as u64;
            ctx.output_now(0, n);
        }
        Ok(ProcessOutcome::Continue)
    }
}

pub fn register(r: &CalculatorRegistry) {
    r.register_fn(
        "BoxTrackerCalculator",
        |_| {
            Ok(Contract::new()
                .input("FRAME", PacketType::of::<ImageFrame>())
                .input("DETECTIONS", PacketType::of::<Detections>())
                .output("TRACKED", PacketType::of::<Detections>())
                .with_sync_sets(vec![vec![0], vec![1]]))
        },
        |_| {
            Ok(Box::new(BoxTracker {
                tracks: Vec::new(),
                next_id: 1,
                max_age: 30,
                search: 0.05,
                match_iou: 0.1,
                prev_frame: None,
            }))
        },
    );
    r.register_fn(
        "TrackedDetectionMergerCalculator",
        |_| {
            Ok(Contract::new()
                .input("DETECTIONS", PacketType::of::<Detections>())
                .input("TRACKED", PacketType::of::<Detections>())
                .output("MERGED", PacketType::of::<Detections>()))
        },
        |_| Ok(Box::new(TrackedDetectionMerger { iou_thr: 0.4 })),
    );
    r.register_fn(
        "DetectionQualityCalculator",
        |_| {
            Ok(Contract::new()
                .input("DETECTIONS", PacketType::of::<Detections>())
                .input("GT", PacketType::of::<Detections>())
                .side_input("STATS", PacketType::of::<SharedQuality>()))
        },
        |_| {
            Ok(Box::new(DetectionQuality {
                iou_thr: 0.3,
                stats: QualityStats::default(),
                sink: None,
            }))
        },
    );
    r.register_fn(
        "DetectionCounterCalculator",
        |_| {
            Ok(Contract::new()
                .input("", PacketType::of::<Detections>())
                .output("", PacketType::of::<u64>())
                .with_timestamp_offset(0))
        },
        |_| Ok(Box::new(DetectionCounter)),
    );
    let _ = HashMap::<u8, u8>::new(); // keep import used under cfg(test) variations
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::timestamp::Timestamp;
    
    #[test]
    fn quality_stats_math() {
        let s = QualityStats {
            true_pos: 8,
            false_pos: 2,
            false_neg: 2,
            frames: 10,
        };
        assert!((s.precision() - 0.8).abs() < 1e-9);
        assert!((s.recall() - 0.8).abs() < 1e-9);
        let empty = QualityStats::default();
        assert_eq!(empty.precision(), 0.0);
        assert_eq!(empty.recall(), 0.0);
    }

    #[test]
    fn refine_moves_towards_bright_region() {
        let tracker = BoxTracker {
            tracks: Vec::new(),
            next_id: 1,
            max_age: 30,
            search: 0.1,
            match_iou: 0.1,
            prev_frame: None,
        };
        // bright box at (0.5, 0.5, 0.2, 0.2); prediction slightly off
        let mut b = ImageFrame::build(64, 64, 1);
        b.fill(0.1)
            .fill_rect(&Rect::new(0.5, 0.5, 0.2, 0.2), &[1.0]);
        let frame = b.finish();
        let refined = tracker.refine(&frame, &Rect::new(0.42, 0.42, 0.2, 0.2));
        let before = frame.cropped(&Rect::new(0.42, 0.42, 0.2, 0.2)).mean();
        let after = frame.cropped(&refined).mean();
        assert!(after >= before, "refinement never worsens appearance");
        assert!(refined.x > 0.42 && refined.y > 0.42, "{refined:?}");
        let _ = Timestamp::new(0);
    }
}
