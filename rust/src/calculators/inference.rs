//! Inference calculators (§6.1): run AOT-compiled XLA models inside the
//! graph, plus decoders from raw output tensors to perception payloads.
//!
//! The paper's object-detection node "consumes an ML model and the
//! associated label map as input side packets, performs ML inference on
//! the incoming selected frames using an inference engine and outputs
//! detection results" — here the engine handle arrives as a side packet
//! and the model is selected by name from the artifact manifest.

use crate::calculator::{Calculator, CalculatorContext, Contract, ProcessOutcome};
use crate::error::{MpError, MpResult};
use crate::packet::PacketType;
use crate::perception::types::{non_max_suppression, Detection, Detections, LandmarkList, Mask, Rect};
use crate::perception::ImageFrame;
use crate::registry::CalculatorRegistry;
use crate::runtime::{InferenceEngine, Tensor};

/// The packet payload carried on raw-tensor streams.
pub type TensorVec = Vec<Tensor>;

/// Runs one model from the artifact manifest on each input frame.
/// Side packet ENGINE: [`InferenceEngine`]. Option `model`: model name.
/// Input: [`ImageFrame`] (auto-flattened NHWC) or [`TensorVec`].
pub struct Inference {
    model: String,
    engine: Option<InferenceEngine>,
}

impl Calculator for Inference {
    fn open(&mut self, ctx: &mut CalculatorContext) -> MpResult<()> {
        self.model = ctx
            .options()
            .get_str("model")
            .ok_or_else(|| MpError::internal("InferenceCalculator needs options.model"))?
            .to_string();
        let engine = ctx.side_input_tag("ENGINE")?.get::<InferenceEngine>()?.clone();
        if !engine.models().contains(&self.model) {
            return Err(MpError::Runtime(format!(
                "model '{}' not in artifact manifest (have: {:?})",
                self.model,
                engine.models()
            )));
        }
        self.engine = Some(engine);
        Ok(())
    }

    fn process(&mut self, ctx: &mut CalculatorContext) -> MpResult<ProcessOutcome> {
        let p = ctx.input(0);
        if p.is_empty() {
            return Ok(ProcessOutcome::Continue);
        }
        let inputs: Vec<Tensor> = if let Ok(frame) = p.get::<ImageFrame>() {
            vec![Tensor::new(
                vec![1, frame.height, frame.width, frame.channels],
                frame.to_tensor(),
            )]
        } else {
            p.get::<TensorVec>()?.clone()
        };
        let engine = self.engine.as_ref().expect("opened");
        let outputs = engine.infer(&self.model, inputs)?;
        ctx.output_now(0, outputs);
        Ok(ProcessOutcome::Continue)
    }
}

/// Decode detector output tensors (`boxes [N,4]` normalized xywh +
/// `scores [N]`) into [`Detections`], with score threshold + NMS and
/// optional anchor clustering.
///
/// Options: `min_score` (0.5), `iou_threshold` (0.4), `class_id` (0),
/// `cluster_dist` (0 = off): anchor-grid detectors light up a *block*
/// of adjacent anchors per object whose pairwise IoU is too low for NMS
/// to merge; clustering fuses hot anchors whose centers are within
/// `cluster_dist` into one score-weighted detection (better
/// localization than any single anchor).
pub struct TensorsToDetections {
    min_score: f32,
    iou_thr: f32,
    class_id: u32,
    cluster_dist: f32,
}

/// Fuse detections whose centers lie within `dist` (single-link
/// connected components); each cluster becomes one detection at the
/// score-weighted mean box with the cluster's max score.
pub fn cluster_detections(dets: &Detections, dist: f32) -> Detections {
    let n = dets.len();
    let mut comp: Vec<usize> = (0..n).collect();
    fn find(comp: &mut Vec<usize>, i: usize) -> usize {
        let mut r = i;
        while comp[r] != r {
            r = comp[r];
        }
        let mut c = i;
        while comp[c] != r {
            let next = comp[c];
            comp[c] = r;
            c = next;
        }
        r
    }
    for i in 0..n {
        for j in i + 1..n {
            let (ci, cj) = (dets[i].bbox.center(), dets[j].bbox.center());
            let d2 = (ci.0 - cj.0).powi(2) + (ci.1 - cj.1).powi(2);
            if d2 <= dist * dist && dets[i].class_id == dets[j].class_id {
                let (ri, rj) = (find(&mut comp, i), find(&mut comp, j));
                if ri != rj {
                    comp[ri] = rj;
                }
            }
        }
    }
    let mut clusters: std::collections::HashMap<usize, Vec<usize>> = Default::default();
    for i in 0..n {
        let r = find(&mut comp, i);
        clusters.entry(r).or_default().push(i);
    }
    let mut out: Detections = clusters
        .values()
        .map(|idxs| {
            let wsum: f32 = idxs.iter().map(|&i| dets[i].score).sum();
            let mut x = 0.0;
            let mut y = 0.0;
            let mut w = 0.0;
            let mut h = 0.0;
            let mut best = 0.0f32;
            for &i in idxs {
                let s = dets[i].score / wsum;
                x += dets[i].bbox.x * s;
                y += dets[i].bbox.y * s;
                w += dets[i].bbox.w * s;
                h += dets[i].bbox.h * s;
                best = best.max(dets[i].score);
            }
            Detection::new(Rect::new(x, y, w, h), best, dets[idxs[0]].class_id)
        })
        .collect();
    // deterministic order: by score desc then position
    out.sort_by(|a, b| {
        b.score
            .partial_cmp(&a.score)
            .unwrap_or(std::cmp::Ordering::Equal)
            .then(a.bbox.x.partial_cmp(&b.bbox.x).unwrap_or(std::cmp::Ordering::Equal))
    });
    out
}

impl Calculator for TensorsToDetections {
    fn open(&mut self, ctx: &mut CalculatorContext) -> MpResult<()> {
        let o = ctx.options();
        self.min_score = o.float_or("min_score", 0.5) as f32;
        self.iou_thr = o.float_or("iou_threshold", 0.4) as f32;
        self.class_id = o.int_or("class_id", 0) as u32;
        self.cluster_dist = o.float_or("cluster_dist", 0.0) as f32;
        Ok(())
    }

    fn process(&mut self, ctx: &mut CalculatorContext) -> MpResult<ProcessOutcome> {
        let p = ctx.input(0);
        if p.is_empty() {
            return Ok(ProcessOutcome::Continue);
        }
        let tensors = p.get::<TensorVec>()?;
        if tensors.len() < 2 {
            return Err(MpError::internal(
                "TensorsToDetections expects [boxes, scores]",
            ));
        }
        let (boxes, scores) = (&tensors[0], &tensors[1]);
        let n = scores.data.len();
        if boxes.data.len() != n * 4 {
            return Err(MpError::internal(format!(
                "boxes/scores mismatch: {} vs {n}",
                boxes.data.len()
            )));
        }
        let mut dets: Detections = Vec::new();
        for i in 0..n {
            let s = scores.data[i];
            if s >= self.min_score {
                let b = &boxes.data[i * 4..i * 4 + 4];
                dets.push(Detection::new(
                    Rect::new(b[0], b[1], b[2], b[3]).clamped(),
                    s,
                    self.class_id,
                ));
            }
        }
        let dets = if self.cluster_dist > 0.0 {
            cluster_detections(&dets, self.cluster_dist)
        } else {
            dets
        };
        let dets = non_max_suppression(dets, self.iou_thr);
        ctx.output_now(0, dets);
        Ok(ProcessOutcome::Continue)
    }
}

/// Decode landmark output (`points [K,2]`) into a [`LandmarkList`].
pub struct TensorsToLandmarks;

impl Calculator for TensorsToLandmarks {
    fn process(&mut self, ctx: &mut CalculatorContext) -> MpResult<ProcessOutcome> {
        let p = ctx.input(0);
        if p.is_empty() {
            return Ok(ProcessOutcome::Continue);
        }
        let tensors = p.get::<TensorVec>()?;
        let t = tensors
            .first()
            .ok_or_else(|| MpError::internal("TensorsToLandmarks expects [points]"))?;
        let k = t.data.len() / 2;
        let points = (0..k)
            .map(|i| (t.data[i * 2].clamp(0.0, 1.0), t.data[i * 2 + 1].clamp(0.0, 1.0)))
            .collect();
        ctx.output_now(0, LandmarkList::new(points));
        Ok(ProcessOutcome::Continue)
    }
}

/// Decode segmentation output (`mask [H,W]`) into a [`Mask`].
pub struct TensorsToMask;

impl Calculator for TensorsToMask {
    fn process(&mut self, ctx: &mut CalculatorContext) -> MpResult<ProcessOutcome> {
        let p = ctx.input(0);
        if p.is_empty() {
            return Ok(ProcessOutcome::Continue);
        }
        let tensors = p.get::<TensorVec>()?;
        let t = tensors
            .first()
            .ok_or_else(|| MpError::internal("TensorsToMask expects [mask]"))?;
        if t.shape.len() < 2 {
            return Err(MpError::internal(format!(
                "mask tensor must be 2-D+, got {:?}",
                t.shape
            )));
        }
        let (h, w) = (t.shape[t.shape.len() - 2], t.shape[t.shape.len() - 1]);
        ctx.output_now(0, Mask::new(w, h, t.data.clone()));
        Ok(ProcessOutcome::Continue)
    }
}

pub fn register(r: &CalculatorRegistry) {
    r.register_fn(
        "InferenceCalculator",
        |_| {
            Ok(Contract::new()
                .input("", PacketType::Any) // ImageFrame or TensorVec
                .output("TENSORS", PacketType::of::<TensorVec>())
                .side_input("ENGINE", PacketType::of::<InferenceEngine>())
                .with_timestamp_offset(0))
        },
        |_| {
            Ok(Box::new(Inference {
                model: String::new(),
                engine: None,
            }))
        },
    );
    r.register_fn(
        "TensorsToDetectionsCalculator",
        |_| {
            Ok(Contract::new()
                .input("TENSORS", PacketType::of::<TensorVec>())
                .output("DETECTIONS", PacketType::of::<Detections>())
                .with_timestamp_offset(0))
        },
        |_| {
            Ok(Box::new(TensorsToDetections {
                min_score: 0.5,
                iou_thr: 0.4,
                class_id: 0,
                cluster_dist: 0.0,
            }))
        },
    );
    r.register_fn(
        "TensorsToLandmarksCalculator",
        |_| {
            Ok(Contract::new()
                .input("TENSORS", PacketType::of::<TensorVec>())
                .output("LANDMARKS", PacketType::of::<LandmarkList>())
                .with_timestamp_offset(0))
        },
        |_| Ok(Box::new(TensorsToLandmarks)),
    );
    r.register_fn(
        "TensorsToMaskCalculator",
        |_| {
            Ok(Contract::new()
                .input("TENSORS", PacketType::of::<TensorVec>())
                .output("MASK", PacketType::of::<Mask>())
                .with_timestamp_offset(0))
        },
        |_| Ok(Box::new(TensorsToMask)),
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn detection_decode_thresholds_and_nms() {
        let boxes = Tensor::new(
            vec![3, 4],
            vec![
                0.1, 0.1, 0.2, 0.2, // A: score .9
                0.11, 0.11, 0.2, 0.2, // B: overlaps A, score .8 -> NMS'd
                0.6, 0.6, 0.2, 0.2, // C: score .3 -> below threshold
            ],
        );
        let scores = Tensor::new(vec![3], vec![0.9, 0.8, 0.3]);
        // decode inline (the calculator's core math)
        let mut dets: Detections = Vec::new();
        for i in 0..3 {
            let s = scores.data[i];
            if s >= 0.5 {
                let b = &boxes.data[i * 4..i * 4 + 4];
                dets.push(Detection::new(Rect::new(b[0], b[1], b[2], b[3]), s, 0));
            }
        }
        let dets = non_max_suppression(dets, 0.4);
        assert_eq!(dets.len(), 1);
        assert!((dets[0].score - 0.9).abs() < 1e-6);
    }

    #[test]
    fn landmark_decode_clamps() {
        let t = Tensor::new(vec![2, 2], vec![-0.5, 0.5, 1.5, 0.25]);
        let k = t.data.len() / 2;
        let points: Vec<(f32, f32)> = (0..k)
            .map(|i| (t.data[i * 2].clamp(0.0, 1.0), t.data[i * 2 + 1].clamp(0.0, 1.0)))
            .collect();
        assert_eq!(points, vec![(0.0, 0.5), (1.0, 0.25)]);
    }
}
