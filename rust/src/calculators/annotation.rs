//! Annotation calculators (§6.1-6.2): overlay detections, landmarks and
//! masks onto camera frames. The default input policy aligns the
//! annotation streams with the frame stream automatically — "the end
//! result is a slightly delayed viewfinder output that is perfectly
//! aligned with the computed and tracked detections, effectively hiding
//! model latency in a dynamic way."

use crate::calculator::{Calculator, CalculatorContext, Contract, ProcessOutcome};
use crate::error::MpResult;
use crate::packet::PacketType;
use crate::perception::image::ImageBuilder;
use crate::perception::types::{Detections, LandmarkList, Mask};
use crate::perception::ImageFrame;
use crate::registry::CalculatorRegistry;

/// Overlays detection boxes on frames (Fig. 1 "detection annotation").
/// The two inputs synchronize on timestamp by the default policy.
pub struct DetectionAnnotator;

impl Calculator for DetectionAnnotator {
    fn process(&mut self, ctx: &mut CalculatorContext) -> MpResult<ProcessOutcome> {
        let frame_in = ctx.input(0);
        if frame_in.is_empty() {
            return Ok(ProcessOutcome::Continue);
        }
        let frame = frame_in.get::<ImageFrame>()?;
        let mut b = ImageBuilder::from_frame(frame);
        let dets_in = ctx.input(1);
        if !dets_in.is_empty() {
            for d in dets_in.get::<Detections>()? {
                // class-coded outline intensity
                let v = 0.5 + 0.25 * (d.class_id % 3) as f32;
                b.stroke_rect(&d.bbox, &[v]);
            }
        }
        ctx.output_now(0, b.finish());
        Ok(ProcessOutcome::Continue)
    }
}

/// Overlays landmark points (+ optional mask) on frames — the §6.2
/// three-stream synchronized annotator.
pub struct LandmarkAnnotator;

impl Calculator for LandmarkAnnotator {
    fn process(&mut self, ctx: &mut CalculatorContext) -> MpResult<ProcessOutcome> {
        let frame_in = ctx.input(0);
        if frame_in.is_empty() {
            return Ok(ProcessOutcome::Continue);
        }
        let frame = frame_in.get::<ImageFrame>()?;
        let mut b = ImageBuilder::from_frame(frame);
        let lm_in = ctx.input(1);
        if !lm_in.is_empty() {
            let lms = lm_in.get::<LandmarkList>()?;
            for &(x, y) in &lms.points {
                let px = (x * (frame.width - 1) as f32) as usize;
                let py = (y * (frame.height - 1) as f32) as usize;
                for c in 0..frame.channels {
                    b.set(px, py, c, 1.0);
                }
            }
        }
        if ctx.input_count() > 2 {
            let mask_in = ctx.input(2);
            if !mask_in.is_empty() {
                let mask = mask_in.get::<Mask>()?;
                // darken background where mask says "not person"
                let (mw, mh) = (mask.width, mask.height);
                for y in 0..frame.height {
                    for x in 0..frame.width {
                        let mx = x * mw / frame.width;
                        let my = y * mh / frame.height;
                        if mask.at(mx, my) < 0.5 {
                            for c in 0..frame.channels {
                                let v = frame.at(x, y, c) * 0.4;
                                b.set(x, y, c, v);
                            }
                        }
                    }
                }
            }
        }
        ctx.output_now(0, b.finish());
        Ok(ProcessOutcome::Continue)
    }
}

pub fn register(r: &CalculatorRegistry) {
    r.register_fn(
        "DetectionAnnotatorCalculator",
        |_| {
            Ok(Contract::new()
                .input("FRAME", PacketType::of::<ImageFrame>())
                .input("DETECTIONS", PacketType::of::<Detections>())
                .output("FRAME", PacketType::of::<ImageFrame>())
                .with_timestamp_offset(0))
        },
        |_| Ok(Box::new(DetectionAnnotator)),
    );
    r.register_fn(
        "LandmarkAnnotatorCalculator",
        |node| {
            let mut c = Contract::new()
                .input("FRAME", PacketType::of::<ImageFrame>())
                .input("LANDMARKS", PacketType::of::<LandmarkList>());
            if node.input_count_with_tag("MASK") > 0 {
                c = c.input("MASK", PacketType::of::<Mask>());
            }
            Ok(c
                .output("FRAME", PacketType::of::<ImageFrame>())
                .with_timestamp_offset(0))
        },
        |_| Ok(Box::new(LandmarkAnnotator)),
    );
}
