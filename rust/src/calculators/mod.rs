//! The calculator library: re-usable inference and processing
//! components (part (c) of the paper's three main parts).

pub mod annotation;
pub mod core;
pub mod flow;
pub mod inference;
pub mod landmark;
pub mod scenarios;
pub mod tracking;
pub mod video;

use crate::registry::CalculatorRegistry;

/// Register every built-in calculator (invoked once for the global
/// registry; tests may call it on private registries).
pub fn register_builtins(r: &CalculatorRegistry) {
    annotation::register(r);
    core::register(r);
    flow::register(r);
    inference::register(r);
    landmark::register(r);
    scenarios::register(r);
    tracking::register(r);
    video::register(r);
}
