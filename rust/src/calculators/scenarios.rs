//! Scenario calculators for the multi-model catalog (SNIPPETS.md
//! Snippets 1 and 2): a deterministic 33-point pose "model", joint-angle
//! decoding, hand and face landmarkers, the holistic merger that
//! synchronizes all three model branches, and per-detection landmarks
//! for the detection→tracking→landmark cascade.
//!
//! Like the reference inference backend, these models are deterministic
//! functions of the image (brightness centroid + fixed canonical
//! shapes): they prove the *pipeline* — multi-branch synchronization,
//! subgraph expansion, swap semantics — not numerics, and they run
//! offline with zero dependencies.

use crate::calculator::{Calculator, CalculatorContext, Contract, ProcessOutcome};
use crate::error::MpResult;
use crate::packet::{Packet, PacketType};
use crate::perception::types::{Detections, LandmarkList};
use crate::perception::ImageFrame;
use crate::registry::CalculatorRegistry;

/// Named joint angles decoded from a pose skeleton (radians). Names are
/// owned strings so a decoded set survives a serving round-trip — the
/// typed data plane decomposes it into a named payload map
/// ([`crate::serving::ServingPayload::from_angles`]) whose entries must
/// reconstruct from the wire, where no `'static` name table exists.
#[derive(Clone, Debug, PartialEq)]
pub struct JointAngles {
    pub angles: Vec<(String, f32)>,
}

/// The synchronized output of the multi-model holistic graph: one pose,
/// two hands and one face mesh, all at the same timestamp.
#[derive(Clone, Debug)]
pub struct HolisticResult {
    pub pose: LandmarkList,
    pub hands: Vec<LandmarkList>,
    pub face: LandmarkList,
}

/// BlazePose-style landmark indices used by the joint-angle decoder.
const L_SHOULDER: usize = 11;
const R_SHOULDER: usize = 12;
const L_ELBOW: usize = 13;
const R_ELBOW: usize = 14;
const L_WRIST: usize = 15;
const R_WRIST: usize = 16;
const L_HIP: usize = 23;
const R_HIP: usize = 24;
const L_KNEE: usize = 25;
const R_KNEE: usize = 26;
const L_ANKLE: usize = 27;
const R_ANKLE: usize = 28;

/// Canonical 33-point skeleton (normalized offsets from the body
/// center), in the BlazePose point order: 0–10 head, 11–22 arms/hands,
/// 23–32 legs/feet.
const POSE_SKELETON: [(f32, f32); 33] = [
    (0.00, -0.42),                                  // 0 nose
    (-0.02, -0.45), (-0.04, -0.45), (-0.06, -0.45), // 1-3 left eye
    (0.02, -0.45), (0.04, -0.45), (0.06, -0.45),    // 4-6 right eye
    (-0.08, -0.43), (0.08, -0.43),                  // 7-8 ears
    (-0.03, -0.38), (0.03, -0.38),                  // 9-10 mouth
    (-0.15, -0.25), (0.15, -0.25),                  // 11-12 shoulders
    (-0.22, -0.05), (0.22, -0.05),                  // 13-14 elbows
    (-0.25, 0.12), (0.25, 0.12),                    // 15-16 wrists
    (-0.27, 0.16), (0.27, 0.16),                    // 17-18 pinkies
    (-0.28, 0.15), (0.28, 0.15),                    // 19-20 indexes
    (-0.26, 0.14), (0.26, 0.14),                    // 21-22 thumbs
    (-0.08, 0.10), (0.08, 0.10),                    // 23-24 hips
    (-0.10, 0.28), (0.10, 0.28),                    // 25-26 knees
    (-0.11, 0.44), (0.11, 0.44),                    // 27-28 ankles
    (-0.12, 0.47), (0.12, 0.47),                    // 29-30 heels
    (-0.15, 0.48), (0.15, 0.48),                    // 31-32 foot tips
];

/// The 21-point canonical hand (wrist + 5 fingers x 4 joints) as
/// normalized offsets from the hand center.
fn hand_points(cx: f32, cy: f32, mirror: f32) -> LandmarkList {
    let mut pts = Vec::with_capacity(21);
    pts.push((cx, cy + 0.04)); // wrist
    for finger in 0..5usize {
        let spread = (finger as f32 - 2.0) * 0.015 * mirror;
        for joint in 1..=4usize {
            let reach = joint as f32 * 0.012;
            pts.push((cx + spread, cy + 0.02 - reach));
        }
    }
    LandmarkList::new(pts)
}

/// Brightness-weighted centroid of a frame's first channel — the
/// deterministic "where is the subject" primitive every scenario model
/// shares. Falls back to the image center on an all-dark frame.
fn brightness_centroid(f: &ImageFrame) -> (f32, f32) {
    let (mut sx, mut sy, mut sw) = (0.0f64, 0.0f64, 0.0f64);
    for y in 0..f.height {
        for x in 0..f.width {
            let v = f.data[(y * f.width + x) * f.channels] as f64;
            sx += v * (x as f64 + 0.5);
            sy += v * (y as f64 + 0.5);
            sw += v;
        }
    }
    if sw <= f64::EPSILON {
        return (0.5, 0.5);
    }
    (
        (sx / sw / f.width as f64) as f32,
        (sy / sw / f.height as f64) as f32,
    )
}

/// Angle (radians) at vertex `b` of the triangle a-b-c.
fn joint_angle(a: (f32, f32), b: (f32, f32), c: (f32, f32)) -> f32 {
    let (ux, uy) = (a.0 - b.0, a.1 - b.1);
    let (vx, vy) = (c.0 - b.0, c.1 - b.1);
    let nu = (ux * ux + uy * uy).sqrt();
    let nv = (vx * vx + vy * vy).sqrt();
    if nu <= f32::EPSILON || nv <= f32::EPSILON {
        return 0.0;
    }
    ((ux * vx + uy * vy) / (nu * nv)).clamp(-1.0, 1.0).acos()
}

/// FRAME → POSE: the 33-point skeleton anchored at the frame's
/// brightness centroid, scaled by the `scale` option (default 0.8).
pub struct PoseDetector {
    scale: f32,
}

impl Calculator for PoseDetector {
    fn open(&mut self, ctx: &mut CalculatorContext) -> MpResult<()> {
        self.scale = ctx.options().float_or("scale", 0.8) as f32;
        Ok(())
    }

    fn process(&mut self, ctx: &mut CalculatorContext) -> MpResult<ProcessOutcome> {
        let p = ctx.input(0);
        if p.is_empty() {
            return Ok(ProcessOutcome::Continue);
        }
        let (cx, cy) = brightness_centroid(p.get::<ImageFrame>()?);
        let points: Vec<(f32, f32)> = POSE_SKELETON
            .iter()
            .map(|&(dx, dy)| {
                (
                    (cx + dx * self.scale).clamp(0.0, 1.0),
                    (cy + dy * self.scale).clamp(0.0, 1.0),
                )
            })
            .collect();
        ctx.output_now(0, LandmarkList::new(points));
        Ok(ProcessOutcome::Continue)
    }
}

/// POSE → ANGLES: elbow and knee angles decoded from the skeleton
/// (Snippet 1's joint-angle post-processing stage).
pub struct JointAngleDecoder;

impl Calculator for JointAngleDecoder {
    fn process(&mut self, ctx: &mut CalculatorContext) -> MpResult<ProcessOutcome> {
        let p = ctx.input(0);
        if p.is_empty() {
            return Ok(ProcessOutcome::Continue);
        }
        let pose = p.get::<LandmarkList>()?;
        let pt = |i: usize| pose.points.get(i).copied().unwrap_or((0.0, 0.0));
        let angles = vec![
            (
                "left_elbow".to_string(),
                joint_angle(pt(L_SHOULDER), pt(L_ELBOW), pt(L_WRIST)),
            ),
            (
                "right_elbow".to_string(),
                joint_angle(pt(R_SHOULDER), pt(R_ELBOW), pt(R_WRIST)),
            ),
            (
                "left_knee".to_string(),
                joint_angle(pt(L_HIP), pt(L_KNEE), pt(L_ANKLE)),
            ),
            (
                "right_knee".to_string(),
                joint_angle(pt(R_HIP), pt(R_KNEE), pt(R_ANKLE)),
            ),
        ];
        ctx.output_now(0, JointAngles { angles });
        Ok(ProcessOutcome::Continue)
    }
}

/// FRAME → HANDS: two 21-point hands placed at the wrist positions the
/// pose skeleton implies for the same centroid.
pub struct HandLandmarker;

impl Calculator for HandLandmarker {
    fn process(&mut self, ctx: &mut CalculatorContext) -> MpResult<ProcessOutcome> {
        let p = ctx.input(0);
        if p.is_empty() {
            return Ok(ProcessOutcome::Continue);
        }
        let (cx, cy) = brightness_centroid(p.get::<ImageFrame>()?);
        let (lw, rw) = (POSE_SKELETON[L_WRIST], POSE_SKELETON[R_WRIST]);
        let hands = vec![
            hand_points(cx + lw.0 * 0.8, cy + lw.1 * 0.8, -1.0),
            hand_points(cx + rw.0 * 0.8, cy + rw.1 * 0.8, 1.0),
        ];
        ctx.output_now(0, hands);
        Ok(ProcessOutcome::Continue)
    }
}

/// FRAME → FACE: a 468-point face mesh (concentric rings around the
/// head position the pose skeleton implies).
pub struct FaceLandmarker;

impl Calculator for FaceLandmarker {
    fn process(&mut self, ctx: &mut CalculatorContext) -> MpResult<ProcessOutcome> {
        let p = ctx.input(0);
        if p.is_empty() {
            return Ok(ProcessOutcome::Continue);
        }
        let (cx, cy) = brightness_centroid(p.get::<ImageFrame>()?);
        let (hx, hy) = (cx, cy + POSE_SKELETON[0].1 * 0.8);
        let mut pts = Vec::with_capacity(468);
        for i in 0..468usize {
            let ring = 1.0 + (i / 52) as f32; // 9 rings x 52 points
            let theta = (i % 52) as f32 / 52.0 * std::f32::consts::TAU;
            let r = 0.01 * ring;
            pts.push((
                (hx + r * theta.cos()).clamp(0.0, 1.0),
                (hy + r * theta.sin()).clamp(0.0, 1.0),
            ));
        }
        ctx.output_now(0, LandmarkList::new(pts));
        Ok(ProcessOutcome::Continue)
    }
}

/// POSE + HANDS + FACE → HOLISTIC: joins the three model branches. No
/// sync sets: the default aligned-timestamp input policy *is* the
/// synchronization claim — Process fires only when all three branches
/// have delivered the same timestamp, so a holistic packet can never mix
/// model outputs from different frames (the paper's §3.2 guarantee,
/// Snippet 2's structure).
pub struct HolisticMerger;

impl Calculator for HolisticMerger {
    fn process(&mut self, ctx: &mut CalculatorContext) -> MpResult<ProcessOutcome> {
        let (pose_in, hands_in, face_in) = (ctx.input(0), ctx.input(1), ctx.input(2));
        if pose_in.is_empty() || hands_in.is_empty() || face_in.is_empty() {
            return Ok(ProcessOutcome::Continue);
        }
        let result = HolisticResult {
            pose: pose_in.get::<LandmarkList>()?.clone(),
            hands: hands_in.get::<Vec<LandmarkList>>()?.clone(),
            face: face_in.get::<LandmarkList>()?.clone(),
        };
        ctx.output(0, Packet::new(result, pose_in.timestamp()));
        Ok(ProcessOutcome::Continue)
    }
}

/// FRAME + DETECTIONS → LANDMARKS: per-detection landmarks (center +
/// four corners of each tracked box) — the cascade's final stage.
pub struct DetectionLandmarks;

impl Calculator for DetectionLandmarks {
    fn process(&mut self, ctx: &mut CalculatorContext) -> MpResult<ProcessOutcome> {
        let (frame_in, dets_in) = (ctx.input(0), ctx.input(1));
        if frame_in.is_empty() || dets_in.is_empty() {
            return Ok(ProcessOutcome::Continue);
        }
        let mut pts = Vec::new();
        for d in dets_in.get::<Detections>()? {
            let b = &d.bbox;
            pts.push(b.center());
            pts.push((b.x, b.y));
            pts.push((b.x + b.w, b.y));
            pts.push((b.x, b.y + b.h));
            pts.push((b.x + b.w, b.y + b.h));
        }
        ctx.output(0, Packet::new(LandmarkList::new(pts), frame_in.timestamp()));
        Ok(ProcessOutcome::Continue)
    }
}

pub fn register(r: &CalculatorRegistry) {
    r.register_fn(
        "PoseDetectorCalculator",
        |_| {
            Ok(Contract::new()
                .input("FRAME", PacketType::of::<ImageFrame>())
                .output("POSE", PacketType::of::<LandmarkList>())
                .with_timestamp_offset(0))
        },
        |_| Ok(Box::new(PoseDetector { scale: 0.8 })),
    );
    r.register_fn(
        "JointAngleCalculator",
        |_| {
            Ok(Contract::new()
                .input("POSE", PacketType::of::<LandmarkList>())
                .output("ANGLES", PacketType::of::<JointAngles>())
                .with_timestamp_offset(0))
        },
        |_| Ok(Box::new(JointAngleDecoder)),
    );
    r.register_fn(
        "HandLandmarkerCalculator",
        |_| {
            Ok(Contract::new()
                .input("FRAME", PacketType::of::<ImageFrame>())
                .output("HANDS", PacketType::of::<Vec<LandmarkList>>())
                .with_timestamp_offset(0))
        },
        |_| Ok(Box::new(HandLandmarker)),
    );
    r.register_fn(
        "FaceLandmarkerCalculator",
        |_| {
            Ok(Contract::new()
                .input("FRAME", PacketType::of::<ImageFrame>())
                .output("FACE", PacketType::of::<LandmarkList>())
                .with_timestamp_offset(0))
        },
        |_| Ok(Box::new(FaceLandmarker)),
    );
    r.register_fn(
        "HolisticMergerCalculator",
        |_| {
            Ok(Contract::new()
                .input("POSE", PacketType::of::<LandmarkList>())
                .input("HANDS", PacketType::of::<Vec<LandmarkList>>())
                .input("FACE", PacketType::of::<LandmarkList>())
                .output("HOLISTIC", PacketType::of::<HolisticResult>())
                .with_timestamp_offset(0))
        },
        |_| Ok(Box::new(HolisticMerger)),
    );
    r.register_fn(
        "DetectionLandmarksCalculator",
        |_| {
            Ok(Contract::new()
                .input("FRAME", PacketType::of::<ImageFrame>())
                .input("DETECTIONS", PacketType::of::<Detections>())
                .output("LANDMARKS", PacketType::of::<LandmarkList>())
                .with_timestamp_offset(0))
        },
        |_| Ok(Box::new(DetectionLandmarks)),
    );
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::perception::types::Rect;

    fn bright_frame(cx: f32, cy: f32) -> ImageFrame {
        let mut b = ImageFrame::build(32, 32, 1);
        b.fill(0.05)
            .fill_rect(&Rect::new(cx - 0.1, cy - 0.1, 0.2, 0.2), &[1.0]);
        b.finish()
    }

    #[test]
    fn centroid_follows_the_bright_region() {
        let (cx, cy) = brightness_centroid(&bright_frame(0.7, 0.3));
        assert!(cx > 0.55, "cx={cx}");
        assert!(cy < 0.45, "cy={cy}");
        // All-dark frame falls back to the center.
        let dark = ImageFrame::new(8, 8, 1, vec![0.0; 64]);
        assert_eq!(brightness_centroid(&dark), (0.5, 0.5));
    }

    #[test]
    fn skeleton_is_33_points_anchored_at_the_centroid() {
        assert_eq!(POSE_SKELETON.len(), 33);
        let skeleton = |f: &ImageFrame| {
            let (cx, cy) = brightness_centroid(f);
            LandmarkList::new(
                POSE_SKELETON
                    .iter()
                    .map(|&(dx, dy)| (cx + dx * 0.5, cy + dy * 0.5))
                    .collect(),
            )
        };
        let left = skeleton(&bright_frame(0.3, 0.5));
        let right = skeleton(&bright_frame(0.7, 0.5));
        assert_eq!(left.points.len(), 33);
        assert!(
            right.centroid().0 > left.centroid().0,
            "skeleton moves with the subject"
        );
    }

    #[test]
    fn joint_angle_degenerate_and_right_angle() {
        assert_eq!(joint_angle((0.0, 0.0), (0.0, 0.0), (1.0, 0.0)), 0.0);
        let right = joint_angle((1.0, 0.0), (0.0, 0.0), (0.0, 1.0));
        assert!((right - std::f32::consts::FRAC_PI_2).abs() < 1e-5);
    }

    #[test]
    fn hand_has_21_points() {
        assert_eq!(hand_points(0.5, 0.5, 1.0).points.len(), 21);
    }
}
