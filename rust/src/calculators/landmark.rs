//! Landmark/mask temporal calculators (§6.2): interpolate sparse
//! landmark and segmentation results back onto every frame timestamp.
//!
//! "To derive the detected landmarks and segmentation masks on all
//! frames, the landmarks and masks are temporally interpolated across
//! frames. The target timestamps for interpolation are simply those of
//! all incoming frames."

use std::collections::VecDeque;

use crate::calculator::{Calculator, CalculatorContext, Contract, ProcessOutcome};
use crate::error::MpResult;
use crate::packet::{Packet, PacketType};
use crate::perception::types::{LandmarkList, Mask};
use crate::perception::ImageFrame;
use crate::registry::CalculatorRegistry;
use crate::timestamp::Timestamp;

/// Generic two-point temporal interpolator driven by frame timestamps.
/// FRAME input supplies target timestamps; VALUE input supplies sparse
/// values. For each frame timestamp between two values, emits the lerp;
/// before the first value, emits nothing; after the last, holds it.
struct TemporalInterpolator<T, F> {
    /// (timestamp µs, value)
    history: VecDeque<(i64, T)>,
    pending_frames: VecDeque<i64>,
    lerp: F,
    hold_last: bool,
}

impl<T: Clone + Send + 'static, F: Fn(&T, &T, f32) -> T + Send> TemporalInterpolator<T, F> {
    fn new(lerp: F) -> Self {
        TemporalInterpolator {
            history: VecDeque::new(),
            pending_frames: VecDeque::new(),
            lerp,
            hold_last: true,
        }
    }

    fn push_value(&mut self, ts: i64, v: T) {
        self.history.push_back((ts, v));
        while self.history.len() > 2 {
            self.history.pop_front();
        }
    }

    /// Emit interpolated values for all pending frame timestamps that
    /// are now bracketed (or holdable).
    fn drain_ready(&mut self, value_bound_exceeds: i64) -> Vec<(i64, T)> {
        let mut out = Vec::new();
        while let Some(&fts) = self.pending_frames.front() {
            match self.history.len() {
                0 => {
                    if value_bound_exceeds > fts {
                        // no value will ever cover this frame; skip it
                        self.pending_frames.pop_front();
                        continue;
                    }
                    break;
                }
                1 => {
                    let (vts, v) = &self.history[0];
                    if fts <= *vts {
                        out.push((fts, v.clone()));
                        self.pending_frames.pop_front();
                    } else if value_bound_exceeds > fts {
                        if self.hold_last {
                            out.push((fts, v.clone()));
                        }
                        self.pending_frames.pop_front();
                    } else {
                        break;
                    }
                }
                _ => {
                    let (t0, v0) = &self.history[0];
                    let (t1, v1) = &self.history[1];
                    if fts <= *t0 {
                        out.push((fts, v0.clone()));
                        self.pending_frames.pop_front();
                    } else if fts <= *t1 {
                        let alpha = (fts - t0) as f32 / (*t1 - *t0).max(1) as f32;
                        out.push((fts, (self.lerp)(v0, v1, alpha)));
                        self.pending_frames.pop_front();
                    } else {
                        // frame beyond newest value: drop oldest, retry
                        self.history.pop_front();
                    }
                }
            }
        }
        out
    }
}

/// Landmark interpolator calculator. Inputs: FRAME (dense),
/// LANDMARKS (sparse). Output: LANDMARKS at every frame timestamp.
pub struct LandmarkInterpolator {
    interp: TemporalInterpolator<LandmarkList, fn(&LandmarkList, &LandmarkList, f32) -> LandmarkList>,
}

impl Calculator for LandmarkInterpolator {
    fn process(&mut self, ctx: &mut CalculatorContext) -> MpResult<ProcessOutcome> {
        let v_in = ctx.input(1);
        if !v_in.is_empty() {
            self.interp
                .push_value(v_in.timestamp().raw(), v_in.get::<LandmarkList>()?.clone());
        }
        let f_in = ctx.input(0);
        if !f_in.is_empty() {
            self.interp.pending_frames.push_back(f_in.timestamp().raw());
        }
        let value_bound = ctx.input_bound(1).0.raw();
        for (ts, v) in self.interp.drain_ready(value_bound) {
            ctx.output(0, Packet::new(v, Timestamp::new(ts)));
        }
        Ok(ProcessOutcome::Continue)
    }
}

/// Mask interpolator calculator (same pattern, pixel-wise lerp).
pub struct MaskInterpolator {
    interp: TemporalInterpolator<Mask, fn(&Mask, &Mask, f32) -> Mask>,
}

impl Calculator for MaskInterpolator {
    fn process(&mut self, ctx: &mut CalculatorContext) -> MpResult<ProcessOutcome> {
        let v_in = ctx.input(1);
        if !v_in.is_empty() {
            self.interp
                .push_value(v_in.timestamp().raw(), v_in.get::<Mask>()?.clone());
        }
        let f_in = ctx.input(0);
        if !f_in.is_empty() {
            self.interp.pending_frames.push_back(f_in.timestamp().raw());
        }
        let value_bound = ctx.input_bound(1).0.raw();
        for (ts, v) in self.interp.drain_ready(value_bound) {
            ctx.output(0, Packet::new(v, Timestamp::new(ts)));
        }
        Ok(ProcessOutcome::Continue)
    }
}

/// Exponential landmark smoother (jitter reduction — the "incremental
/// improvement" §1 motivates; also an ablation point).
pub struct LandmarkSmoother {
    alpha: f32,
    state: Option<LandmarkList>,
}

impl Calculator for LandmarkSmoother {
    fn open(&mut self, ctx: &mut CalculatorContext) -> MpResult<()> {
        self.alpha = ctx.options().float_or("alpha", 0.5) as f32;
        Ok(())
    }

    fn process(&mut self, ctx: &mut CalculatorContext) -> MpResult<ProcessOutcome> {
        let p = ctx.input(0);
        if p.is_empty() {
            return Ok(ProcessOutcome::Continue);
        }
        let lm = p.get::<LandmarkList>()?;
        let sm = match &self.state {
            Some(prev) => prev.lerp(lm, self.alpha),
            None => lm.clone(),
        };
        self.state = Some(sm.clone());
        ctx.output_now(0, sm);
        Ok(ProcessOutcome::Continue)
    }
}

pub fn register(r: &CalculatorRegistry) {
    r.register_fn(
        "LandmarkInterpolatorCalculator",
        |_| {
            Ok(Contract::new()
                .input("FRAME", PacketType::of::<ImageFrame>())
                .input("LANDMARKS", PacketType::of::<LandmarkList>())
                .output("LANDMARKS", PacketType::of::<LandmarkList>())
                .with_sync_sets(vec![vec![0], vec![1]]))
        },
        |_| {
            Ok(Box::new(LandmarkInterpolator {
                interp: TemporalInterpolator::new(LandmarkList::lerp as _),
            }))
        },
    );
    r.register_fn(
        "MaskInterpolatorCalculator",
        |_| {
            Ok(Contract::new()
                .input("FRAME", PacketType::of::<ImageFrame>())
                .input("MASK", PacketType::of::<Mask>())
                .output("MASK", PacketType::of::<Mask>())
                .with_sync_sets(vec![vec![0], vec![1]]))
        },
        |_| {
            Ok(Box::new(MaskInterpolator {
                interp: TemporalInterpolator::new(Mask::lerp as _),
            }))
        },
    );
    r.register_fn(
        "LandmarkSmootherCalculator",
        |_| {
            Ok(Contract::new()
                .input("", PacketType::of::<LandmarkList>())
                .output("", PacketType::of::<LandmarkList>())
                .with_timestamp_offset(0))
        },
        |_| {
            Ok(Box::new(LandmarkSmoother {
                alpha: 0.5,
                state: None,
            }))
        },
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    fn lm(x: f32) -> LandmarkList {
        LandmarkList::new(vec![(x, x)])
    }

    #[test]
    fn interpolator_brackets_frames() {
        let mut it: TemporalInterpolator<LandmarkList, _> =
            TemporalInterpolator::new(|a: &LandmarkList, b: &LandmarkList, t: f32| a.lerp(b, t));
        it.push_value(0, lm(0.0));
        it.push_value(100, lm(1.0));
        for f in [0i64, 25, 50, 75, 100] {
            it.pending_frames.push_back(f);
        }
        let out = it.drain_ready(101);
        assert_eq!(out.len(), 5);
        let xs: Vec<f32> = out.iter().map(|(_, l)| l.points[0].0).collect();
        assert_eq!(xs, vec![0.0, 0.25, 0.5, 0.75, 1.0]);
    }

    #[test]
    fn interpolator_waits_for_bracketing_value() {
        let mut it: TemporalInterpolator<LandmarkList, _> =
            TemporalInterpolator::new(|a: &LandmarkList, b: &LandmarkList, t: f32| a.lerp(b, t));
        it.push_value(0, lm(0.0));
        it.pending_frames.push_back(50);
        // value stream settled only to 10: frame@50 must wait
        assert!(it.drain_ready(10).is_empty());
        // once the value stream is settled past 50 with no new value,
        // hold the last one
        let out = it.drain_ready(60);
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].1.points[0].0, 0.0);
    }

    #[test]
    fn interpolator_skips_frames_before_first_value() {
        let mut it: TemporalInterpolator<LandmarkList, _> =
            TemporalInterpolator::new(|a: &LandmarkList, b: &LandmarkList, t: f32| a.lerp(b, t));
        it.pending_frames.push_back(5);
        // no value ever arrives at/before 5 and the bound passed it
        let out = it.drain_ready(10);
        assert!(out.is_empty());
        assert!(it.pending_frames.is_empty(), "frame consumed, not stuck");
    }

    #[test]
    fn interpolator_slides_window_forward() {
        let mut it: TemporalInterpolator<LandmarkList, _> =
            TemporalInterpolator::new(|a: &LandmarkList, b: &LandmarkList, t: f32| a.lerp(b, t));
        it.push_value(0, lm(0.0));
        it.push_value(10, lm(1.0));
        it.push_value(20, lm(0.5)); // window slides to [10, 20]
        it.pending_frames.push_back(15);
        let out = it.drain_ready(21);
        assert_eq!(out.len(), 1);
        assert!((out[0].1.points[0].0 - 0.75).abs() < 1e-6);
    }
}
