//! Flow-control calculators (§4.1.4, Fig. 3): the node-based system that
//! drops packets according to real-time constraints.
//!
//! "The second system consists of inserting special nodes which can drop
//! packets ... Typically, these nodes use special input policies to be
//! able to make fast decisions on their inputs."

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use crate::calculator::{Calculator, CalculatorContext, Contract, ProcessOutcome};
use crate::error::MpResult;
use crate::packet::{Packet, PacketType};
use crate::registry::CalculatorRegistry;
use crate::timestamp::{Timestamp, TimestampBound};

/// Shared drop counter so benches/tests can observe shedding (Fig. 3
/// evaluation: "measure drops, in-flight, latency").
#[derive(Clone, Default)]
pub struct DropCounter(pub Arc<AtomicU64>);

impl DropCounter {
    pub fn new() -> DropCounter {
        DropCounter::default()
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// The Fig. 3 flow limiter: admits packets from its main input into the
/// downstream subgraph while fewer than `max_in_flight` timestamps are
/// being processed; the FINISHED back-edge input (loopback from the
/// subgraph's final output) retires them. Excess packets are dropped
/// **upstream**, avoiding "the wasted work that would result from
/// partially processing a timestamp and then dropping packets between
/// intermediate stages".
///
/// Uses the Immediate input policy: admission decisions must react to
/// each packet as it arrives, not wait for cross-stream settling.
pub struct FlowLimiter {
    max_in_flight: usize,
    in_flight: usize,
    dropped: u64,
    counter: Option<DropCounter>,
}

impl Calculator for FlowLimiter {
    fn open(&mut self, ctx: &mut CalculatorContext) -> MpResult<()> {
        self.max_in_flight = ctx.options().int_or("max_in_flight", 1).max(1) as usize;
        if let Ok(p) = ctx.side_input_tag("DROPS") {
            if !p.is_empty() {
                self.counter = Some(p.get::<DropCounter>()?.clone());
            }
        }
        Ok(())
    }

    fn process(&mut self, ctx: &mut CalculatorContext) -> MpResult<ProcessOutcome> {
        // FINISHED retires an in-flight timestamp.
        let fin = ctx.input(1);
        if !fin.is_empty() {
            self.in_flight = self.in_flight.saturating_sub(1);
        }
        let main = ctx.input(0);
        if !main.is_empty() {
            if self.in_flight < self.max_in_flight {
                self.in_flight += 1;
                let p = main.clone();
                ctx.output(0, p);
            } else {
                self.dropped += 1;
                if let Some(c) = &self.counter {
                    c.0.fetch_add(1, Ordering::Relaxed);
                }
                // Even when dropping, settle downstream at this
                // timestamp so synchronization with side branches that
                // did receive the frame is not stalled.
                let bound = TimestampBound::after_packet(main.timestamp());
                ctx.set_next_timestamp_bound(0, bound);
            }
        }
        Ok(ProcessOutcome::Continue)
    }
}

/// Passes through at most one packet per `period_us` of *timestamp*
/// time: a deterministic rate limiter (the "limiting frequency" part of
/// the §6.1 frame-selection node, usable standalone).
pub struct PacketThinner {
    period_us: i64,
    next_allowed: Timestamp,
}

impl Calculator for PacketThinner {
    fn open(&mut self, ctx: &mut CalculatorContext) -> MpResult<()> {
        self.period_us = ctx.options().int_or("period_us", 1).max(1);
        Ok(())
    }

    fn process(&mut self, ctx: &mut CalculatorContext) -> MpResult<ProcessOutcome> {
        let p = ctx.input(0);
        if !p.is_empty() {
            let ts = p.timestamp();
            if ts >= self.next_allowed {
                self.next_allowed = Timestamp::new(
                    (ts.micros() / self.period_us + 1) * self.period_us,
                );
                let p = p.clone();
                ctx.output(0, p);
            }
        }
        Ok(ProcessOutcome::Continue)
    }
}

/// Emits every packet it receives but never more than `capacity` queued
/// timestamps downstream, *blocking* semantics (real back-pressure is
/// provided by the framework's `max_queue_size`; this node instead keeps
/// the most recent packet, dropping stale ones — a "real-time queue" of
/// size 1). Mirrors MediaPipe's RealTimeFlowLimiter usage for display
/// paths.
pub struct LatestOnly {
    latest: Option<Packet>,
}

impl Calculator for LatestOnly {
    fn process(&mut self, ctx: &mut CalculatorContext) -> MpResult<ProcessOutcome> {
        let p = ctx.input(0);
        if !p.is_empty() {
            self.latest = Some(p.clone());
        }
        // Forward the newest immediately; stale intermediates are
        // replaced before a downstream slow consumer sees them.
        if let Some(latest) = self.latest.take() {
            ctx.output(0, latest);
        }
        Ok(ProcessOutcome::Continue)
    }
}

pub fn register(r: &CalculatorRegistry) {
    r.register_fn(
        "FlowLimiterCalculator",
        |_| {
            Ok(Contract::new()
                .input("", PacketType::Any)
                .input("FINISHED", PacketType::Any)
                .output("", PacketType::Any)
                .optional_side_input("DROPS", PacketType::of::<DropCounter>())
                .with_policy(crate::calculator::InputPolicyKind::Immediate))
        },
        |_| {
            Ok(Box::new(FlowLimiter {
                max_in_flight: 1,
                in_flight: 0,
                dropped: 0,
                counter: None,
            }))
        },
    );
    r.register_fn(
        "PacketThinnerCalculator",
        |node| {
            // `declare_offset: true` lets the thinner promise offset 0 so
            // dropped timestamps still settle downstream (§4.1.2 fn.6) —
            // benches contrast joins with and without the declaration.
            let mut c = Contract::new()
                .input("", PacketType::Any)
                .output("", PacketType::Any);
            if node.options.bool_or("declare_offset", false) {
                c = c.with_timestamp_offset(0);
            }
            Ok(c)
        },
        |_| {
            Ok(Box::new(PacketThinner {
                period_us: 1,
                next_allowed: Timestamp::MIN,
            }))
        },
    );
    r.register_fn(
        "LatestOnlyCalculator",
        |_| {
            Ok(Contract::new()
                .input("", PacketType::Any)
                .output("", PacketType::Any)
                .with_policy(crate::calculator::InputPolicyKind::Immediate))
        },
        |_| Ok(Box::new(LatestOnly { latest: None })),
    );
}
