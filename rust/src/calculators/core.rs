//! Core reusable calculators: the framework's standard library of
//! plumbing nodes (pass-through, gating, mux/demux, sources, sinks,
//! resampling). These are the "collection of re-usable ... processing
//! components" of the paper's part (c).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::calculator::{
    Calculator, CalculatorContext, Contract, ProcessOutcome,
};
use crate::error::MpResult;
use crate::packet::{Packet, PacketType};
use crate::registry::CalculatorRegistry;
use crate::timestamp::{Timestamp, TimestampBound};

// ---------------------------------------------------------------------
// PassThroughCalculator
// ---------------------------------------------------------------------

/// Forwards every input packet unchanged (N inputs -> N outputs,
/// port-wise). The canonical trivial calculator.
pub struct PassThrough;

impl Calculator for PassThrough {
    fn process(&mut self, ctx: &mut CalculatorContext) -> MpResult<ProcessOutcome> {
        for i in 0..ctx.input_count() {
            let p = ctx.input(i).clone();
            if !p.is_empty() {
                ctx.output(i, p);
            }
        }
        Ok(ProcessOutcome::Continue)
    }
}

// ---------------------------------------------------------------------
// CounterSourceCalculator
// ---------------------------------------------------------------------

/// Source emitting `count` packets of `u64` at `period_us` timestamp
/// intervals starting at `start_us`. The workhorse of tests/benches.
/// Options: `count` (default 10), `period_us` (default 1), `start_us`
/// (default 0), `batch` (packets per Process call, default 1).
pub struct CounterSource {
    next: u64,
    count: u64,
    period_us: i64,
    start_us: i64,
    batch: u64,
}

impl Calculator for CounterSource {
    fn open(&mut self, ctx: &mut CalculatorContext) -> MpResult<()> {
        let o = ctx.options();
        self.count = o.int_or("count", 10) as u64;
        self.period_us = o.int_or("period_us", 1);
        self.start_us = o.int_or("start_us", 0);
        self.batch = o.int_or("batch", 1).max(1) as u64;
        Ok(())
    }

    fn process(&mut self, ctx: &mut CalculatorContext) -> MpResult<ProcessOutcome> {
        for _ in 0..self.batch {
            if self.next >= self.count {
                return Ok(ProcessOutcome::Stop);
            }
            let ts = Timestamp::new(self.start_us + self.next as i64 * self.period_us);
            ctx.output(0, Packet::new(self.next, ts));
            self.next += 1;
        }
        if self.next >= self.count {
            Ok(ProcessOutcome::Stop)
        } else {
            Ok(ProcessOutcome::Continue)
        }
    }
}

// ---------------------------------------------------------------------
// SidePacketToStreamCalculator
// ---------------------------------------------------------------------

/// Emits the side packet once on its output stream at `Timestamp::PRESTREAM`
/// (or at `at_us` if set), then stops producing.
pub struct SidePacketToStream {
    emitted: bool,
}

impl Calculator for SidePacketToStream {
    fn open(&mut self, ctx: &mut CalculatorContext) -> MpResult<()> {
        let at = ctx.options().get_int("at_us");
        let ts = match at {
            Some(us) => Timestamp::new(us),
            None => Timestamp::PRESTREAM,
        };
        let p = ctx.side_input(0).clone().at(ts);
        ctx.output(0, p);
        self.emitted = true;
        Ok(())
    }

    fn process(&mut self, _ctx: &mut CalculatorContext) -> MpResult<ProcessOutcome> {
        Ok(ProcessOutcome::Stop)
    }
}

// ---------------------------------------------------------------------
// GateCalculator
// ---------------------------------------------------------------------

/// Forwards packets on the data input while the most recent packet on
/// the ALLOW stream (a `bool`) is true. Control and data are
/// timestamp-synchronized by the default input policy (matching
/// MediaPipe's GateCalculator): a control packet at timestamp T governs
/// data from T onwards, deterministically.
pub struct Gate {
    allow: bool,
}

impl Calculator for Gate {
    fn open(&mut self, ctx: &mut CalculatorContext) -> MpResult<()> {
        self.allow = ctx.options().bool_or("initial", true);
        Ok(())
    }

    fn process(&mut self, ctx: &mut CalculatorContext) -> MpResult<ProcessOutcome> {
        let ctrl = ctx.input(1);
        if !ctrl.is_empty() {
            self.allow = *ctrl.get::<bool>()?;
        }
        let data = ctx.input(0);
        if !data.is_empty() && self.allow {
            let p = data.clone();
            ctx.output(0, p);
        }
        Ok(ProcessOutcome::Continue)
    }
}

// ---------------------------------------------------------------------
// MuxCalculator / RoundRobinDemuxCalculator
// ---------------------------------------------------------------------

/// Forwards the packet from whichever of its IN ports has one, merging
/// several streams into one (inputs must have disjoint timestamps —
/// enforced by the output stream's monotonicity check).
pub struct Mux;

impl Calculator for Mux {
    fn process(&mut self, ctx: &mut CalculatorContext) -> MpResult<ProcessOutcome> {
        for i in 0..ctx.input_count() {
            let p = ctx.input(i);
            if !p.is_empty() {
                let p = p.clone();
                ctx.output(0, p);
                break; // one packet per timestamp
            }
        }
        Ok(ProcessOutcome::Continue)
    }
}

/// Splits the input stream into N interleaving subsets of packets, each
/// going to a separate output stream — the demultiplexing node of the
/// §6.2 face-landmark/segmentation example.
pub struct RoundRobinDemux {
    next: usize,
}

impl Calculator for RoundRobinDemux {
    fn process(&mut self, ctx: &mut CalculatorContext) -> MpResult<ProcessOutcome> {
        let p = ctx.input(0).clone();
        if !p.is_empty() {
            let port = self.next;
            self.next = (self.next + 1) % ctx.output_count();
            // Other outputs learn that this timestamp carries nothing
            // for them (keeps downstream synchronization fast).
            let bound = TimestampBound::after_packet(p.timestamp());
            for o in 0..ctx.output_count() {
                if o == port {
                    ctx.output(o, p.clone());
                } else {
                    ctx.set_next_timestamp_bound(o, bound);
                }
            }
        }
        Ok(ProcessOutcome::Continue)
    }
}

// ---------------------------------------------------------------------
// PacketClonerCalculator
// ---------------------------------------------------------------------

/// Emits the most recent packet from the VALUE input whenever a TICK
/// packet arrives (cloned at the tick's timestamp). MediaPipe's
/// PacketClonerCalculator; used to align slow data to a fast clock.
pub struct PacketCloner {
    latest: Option<Packet>,
}

impl Calculator for PacketCloner {
    fn process(&mut self, ctx: &mut CalculatorContext) -> MpResult<ProcessOutcome> {
        let v = ctx.input(1);
        if !v.is_empty() {
            self.latest = Some(v.clone());
        }
        let tick = ctx.input(0);
        if !tick.is_empty() {
            if let Some(latest) = &self.latest {
                let out = latest.at(tick.timestamp());
                ctx.output(0, out);
            }
        }
        Ok(ProcessOutcome::Continue)
    }
}

// ---------------------------------------------------------------------
// PreviousLoopbackCalculator
// ---------------------------------------------------------------------

/// Pairs each MAIN packet with the most recent LOOP packet from a
/// previous timestamp (the LOOP input is a declared back edge).
/// Emits the previous loop value — or an empty marker at the first
/// timestamp — so cyclic graphs stay live. Mirrors MediaPipe's
/// PreviousLoopbackCalculator.
pub struct PreviousLoopback {
    prev: Option<Packet>,
}

impl Calculator for PreviousLoopback {
    fn process(&mut self, ctx: &mut CalculatorContext) -> MpResult<ProcessOutcome> {
        let loopb = ctx.input(1);
        if !loopb.is_empty() {
            self.prev = Some(loopb.clone());
        }
        let main = ctx.input(0);
        if !main.is_empty() {
            let ts = main.timestamp();
            match &self.prev {
                Some(p) => {
                    let out = p.at(ts);
                    ctx.output(0, out);
                }
                None => ctx.output(0, Packet::new(LoopbackEmpty, ts)),
            }
        }
        Ok(ProcessOutcome::Continue)
    }
}

/// Marker payload emitted by [`PreviousLoopback`] before the first loop
/// value exists.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct LoopbackEmpty;

// ---------------------------------------------------------------------
// CallbackSinkCalculator (test/instrumentation aid)
// ---------------------------------------------------------------------

/// Invokes a user closure for every input packet. Register per-graph by
/// passing the closure through a side packet of type [`SinkFn`].
pub struct CallbackSink;

/// The closure payload consumed by [`CallbackSink`].
pub type SinkFn = Arc<dyn Fn(&Packet) + Send + Sync>;

impl Calculator for CallbackSink {
    fn process(&mut self, ctx: &mut CalculatorContext) -> MpResult<ProcessOutcome> {
        let f = ctx.side_input(0).get::<SinkFn>()?.clone();
        for i in 0..ctx.input_count() {
            let p = ctx.input(i);
            if !p.is_empty() {
                f(p);
            }
        }
        Ok(ProcessOutcome::Continue)
    }
}

// ---------------------------------------------------------------------
// SequenceShiftCalculator
// ---------------------------------------------------------------------

/// Re-timestamps packets by `offset` positions within the sequence
/// (positive = packet content appears at a later timestamp). MediaPipe's
/// SequenceShiftCalculator, used for temporal alignment.
pub struct SequenceShift {
    offset: i64,
    buffer: Vec<Packet>,
    timestamps: Vec<Timestamp>,
}

impl Calculator for SequenceShift {
    fn open(&mut self, ctx: &mut CalculatorContext) -> MpResult<()> {
        self.offset = ctx.options().int_or("offset", 1);
        Ok(())
    }

    fn process(&mut self, ctx: &mut CalculatorContext) -> MpResult<ProcessOutcome> {
        let p = ctx.input(0);
        if p.is_empty() {
            return Ok(ProcessOutcome::Continue);
        }
        let ts = ctx.input_timestamp();
        if self.offset > 0 {
            // Packet k surfaces at the timestamp of packet k+offset.
            self.buffer.push(p.clone());
            self.timestamps.push(ts);
            if self.buffer.len() > self.offset as usize {
                let out = self.buffer.remove(0);
                self.timestamps.remove(0);
                let out = out.at(ts);
                ctx.output(0, out);
            }
        } else {
            // Non-positive offsets pass through unchanged (offset 0) —
            // negative shifts would violate monotonicity.
            let out = p.clone();
            ctx.output(0, out);
        }
        Ok(ProcessOutcome::Continue)
    }
}

// ---------------------------------------------------------------------
// BusyWorkCalculator (bench workload)
// ---------------------------------------------------------------------

/// Burns `work_us` microseconds of CPU per packet, then forwards it.
/// The synthetic stand-in for heavy processing stages in Fig. 1/3
/// benches (deterministic spin, not sleep, to model CPU contention).
pub struct BusyWork {
    work_us: u64,
}

/// Global knob letting benches scale all BusyWork nodes at once.
pub static BUSY_WORK_SCALE_PERCENT: AtomicU64 = AtomicU64::new(100);

impl Calculator for BusyWork {
    fn open(&mut self, ctx: &mut CalculatorContext) -> MpResult<()> {
        self.work_us = ctx.options().int_or("work_us", 100) as u64;
        Ok(())
    }

    fn process(&mut self, ctx: &mut CalculatorContext) -> MpResult<ProcessOutcome> {
        let scale = BUSY_WORK_SCALE_PERCENT.load(Ordering::Relaxed);
        let dur = std::time::Duration::from_micros(self.work_us * scale / 100);
        let start = std::time::Instant::now();
        while start.elapsed() < dur {
            std::hint::spin_loop();
        }
        let p = ctx.input(0).clone();
        if !p.is_empty() {
            ctx.output(0, p);
        }
        Ok(ProcessOutcome::Continue)
    }
}

// ---------------------------------------------------------------------
// CollectorCalculator (test aid): accumulates into a shared Vec
// ---------------------------------------------------------------------

/// Appends every `(timestamp, data_id)` it sees to a shared vector
/// provided via side packet — the standard assertion point in tests.
pub struct Collector;

/// Shared sink payload for [`Collector`].
pub type Collected = Arc<Mutex<Vec<(Timestamp, u64)>>>;

impl Calculator for Collector {
    fn process(&mut self, ctx: &mut CalculatorContext) -> MpResult<ProcessOutcome> {
        let sink = ctx.side_input(0).get::<Collected>()?.clone();
        for i in 0..ctx.input_count() {
            let p = ctx.input(i);
            if !p.is_empty() {
                sink.lock().unwrap().push((p.timestamp(), p.data_id()));
            }
        }
        Ok(ProcessOutcome::Continue)
    }
}

// ---------------------------------------------------------------------
// registration
// ---------------------------------------------------------------------

pub fn register(r: &CalculatorRegistry) {
    r.register_fn(
        "PassThroughCalculator",
        |node| {
            let n = node.inputs.len().max(1);
            Ok(Contract::new()
                .input_repeated("", PacketType::Any, n)
                .output_repeated("", PacketType::Any, node.outputs.len().max(1))
                .with_timestamp_offset(0))
        },
        |_| Ok(Box::new(PassThrough)),
    );
    r.register_fn(
        "CounterSourceCalculator",
        |_| Ok(Contract::new().output("", PacketType::of::<u64>())),
        |_| {
            Ok(Box::new(CounterSource {
                next: 0,
                count: 0,
                period_us: 1,
                start_us: 0,
                batch: 1,
            }))
        },
    );
    r.register_fn(
        "SidePacketToStreamCalculator",
        |_| {
            Ok(Contract::new()
                .output("", PacketType::Any)
                .side_input("PACKET", PacketType::Any))
        },
        |_| Ok(Box::new(SidePacketToStream { emitted: false })),
    );
    r.register_fn(
        "GateCalculator",
        |_| {
            Ok(Contract::new()
                .input("", PacketType::Any)
                .input("ALLOW", PacketType::of::<bool>())
                .output("", PacketType::Any)
                .with_timestamp_offset(0))
        },
        |_| Ok(Box::new(Gate { allow: true })),
    );
    r.register_fn(
        "MuxCalculator",
        |node| {
            Ok(Contract::new()
                .input_repeated("IN", PacketType::Any, node.input_count_with_tag("IN").max(1))
                .output("", PacketType::Any)
                .with_timestamp_offset(0))
        },
        |_| Ok(Box::new(Mux)),
    );
    r.register_fn(
        "RoundRobinDemuxCalculator",
        |node| {
            Ok(Contract::new()
                .input("", PacketType::Any)
                .output_repeated(
                    "OUT",
                    PacketType::Any,
                    node.output_count_with_tag("OUT").max(1),
                ))
        },
        |_| Ok(Box::new(RoundRobinDemux { next: 0 })),
    );
    r.register_fn(
        "PacketClonerCalculator",
        |_| {
            Ok(Contract::new()
                .input("TICK", PacketType::Any)
                .input("VALUE", PacketType::Any)
                .output("", PacketType::Any)
                .with_sync_sets(vec![vec![0], vec![1]]))
        },
        |_| Ok(Box::new(PacketCloner { latest: None })),
    );
    r.register_fn(
        "PreviousLoopbackCalculator",
        |_| {
            Ok(Contract::new()
                .input("MAIN", PacketType::Any)
                .input("LOOP", PacketType::Any)
                .output("PREV", PacketType::Any)
                .with_sync_sets(vec![vec![0], vec![1]]))
        },
        |_| Ok(Box::new(PreviousLoopback { prev: None })),
    );
    r.register_fn(
        "CallbackSinkCalculator",
        |node| {
            Ok(Contract::new()
                .input_repeated("", PacketType::Any, node.inputs.len().max(1))
                .side_input("CALLBACK", PacketType::of::<SinkFn>()))
        },
        |_| Ok(Box::new(CallbackSink)),
    );
    r.register_fn(
        "SequenceShiftCalculator",
        |_| {
            Ok(Contract::new()
                .input("", PacketType::Any)
                .output("", PacketType::Any))
        },
        |_| {
            Ok(Box::new(SequenceShift {
                offset: 1,
                buffer: Vec::new(),
                timestamps: Vec::new(),
            }))
        },
    );
    r.register_fn(
        "BusyWorkCalculator",
        |_| {
            Ok(Contract::new()
                .input("", PacketType::Any)
                .output("", PacketType::Any)
                .with_timestamp_offset(0))
        },
        |_| Ok(Box::new(BusyWork { work_us: 100 })),
    );
    r.register_fn(
        "CollectorCalculator",
        |node| {
            Ok(Contract::new()
                .input_repeated("", PacketType::Any, node.inputs.len().max(1))
                .side_input("SINK", PacketType::of::<Collected>()))
        },
        |_| Ok(Box::new(Collector)),
    );
}
