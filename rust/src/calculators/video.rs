//! Video calculators (§6.1): synthetic camera source, frame selection
//! (rate limiting + scene-change analysis), and image transforms.

use crate::calculator::{Calculator, CalculatorContext, Contract, ProcessOutcome};
use crate::error::{MpError, MpResult};
use crate::packet::{Packet, PacketType};
use crate::perception::{Detections, ImageFrame, SyntheticWorld};
use crate::registry::CalculatorRegistry;
use crate::timestamp::Timestamp;

/// Synthetic camera (DESIGN.md substitution for the live feed). Emits
/// [`ImageFrame`]s at `fps` on FRAME, and ground-truth [`Detections`]
/// on the optional GT output.
///
/// Options: `width`, `height` (default 64), `objects` (3), `seed` (1),
/// `frames` (total; default 300), `fps` (30), `scene_cut_every` (0),
/// `noise` (0.02), `min_size`/`max_size` (object size range, default
/// 0.08..0.2 — the compiled detector reliably sees >= ~0.10), and
/// `realtime` (false: emit as fast as downstream allows; true: sleep to
/// wall-clock pace).
pub struct SyntheticVideoSource {
    world: Option<SyntheticWorld>,
    emitted: u64,
    total: u64,
    period_us: i64,
    realtime: bool,
    started: Option<std::time::Instant>,
}

impl Calculator for SyntheticVideoSource {
    fn open(&mut self, ctx: &mut CalculatorContext) -> MpResult<()> {
        let o = ctx.options();
        let w = o.int_or("width", 64) as usize;
        let h = o.int_or("height", 64) as usize;
        let mut world = SyntheticWorld::new(w, h, o.int_or("objects", 3) as usize, o.int_or("seed", 1) as u64)
            .with_noise(o.float_or("noise", 0.02) as f32)
            .with_object_sizes(
                o.float_or("min_size", 0.08) as f32,
                o.float_or("max_size", 0.2) as f32,
            );
        let cuts = o.int_or("scene_cut_every", 0);
        if cuts > 0 {
            world = world.with_scene_cuts(cuts as u64);
        }
        self.world = Some(world);
        self.total = o.int_or("frames", 300) as u64;
        let fps = o.int_or("fps", 30).max(1);
        self.period_us = 1_000_000 / fps;
        self.realtime = o.bool_or("realtime", false);
        self.started = Some(std::time::Instant::now());
        Ok(())
    }

    fn process(&mut self, ctx: &mut CalculatorContext) -> MpResult<ProcessOutcome> {
        if self.emitted >= self.total {
            return Ok(ProcessOutcome::Stop);
        }
        let world = self.world.as_mut().expect("opened");
        world.step();
        let ts = Timestamp::new(self.emitted as i64 * self.period_us);
        if self.realtime {
            let target = std::time::Duration::from_micros((self.emitted * self.period_us as u64) as u64);
            let elapsed = self.started.unwrap().elapsed();
            if target > elapsed {
                std::thread::sleep(target - elapsed);
            }
        }
        let frame = world.render();
        ctx.output(0, Packet::new(frame, ts));
        if ctx.output_count() > 1 {
            ctx.output(1, Packet::new(world.ground_truth(), ts));
        }
        self.emitted += 1;
        if self.emitted >= self.total {
            Ok(ProcessOutcome::Stop)
        } else {
            Ok(ProcessOutcome::Continue)
        }
    }
}

/// §6.1 frame selection: "a frame-selection node first selects frames to
/// go through detection based on limiting frequency or scene-change
/// analysis, and passes them to the detector while dropping the
/// irrelevant frames."
///
/// Options: `mode` = "period" | "scene_change" | "both" (default
/// "period"), `period` = pass every k-th frame (default 5),
/// `threshold` = mean-absolute-difference trigger (default 0.05).
pub struct FrameSelection {
    mode: String,
    period: u64,
    threshold: f32,
    seen: u64,
    last_selected: Option<ImageFrame>,
}

impl Calculator for FrameSelection {
    fn open(&mut self, ctx: &mut CalculatorContext) -> MpResult<()> {
        let o = ctx.options();
        self.mode = o.str_or("mode", "period").to_string();
        self.period = o.int_or("period", 5).max(1) as u64;
        self.threshold = o.float_or("threshold", 0.05) as f32;
        Ok(())
    }

    fn process(&mut self, ctx: &mut CalculatorContext) -> MpResult<ProcessOutcome> {
        let p = ctx.input(0);
        if p.is_empty() {
            return Ok(ProcessOutcome::Continue);
        }
        let frame = p.get::<ImageFrame>()?;
        let idx = self.seen;
        self.seen += 1;
        let periodic = idx % self.period == 0;
        let changed = match &self.last_selected {
            Some(prev) if prev.data.len() == frame.data.len() => {
                frame.mad(prev) > self.threshold
            }
            _ => true,
        };
        let selected = match self.mode.as_str() {
            "period" => periodic,
            "scene_change" => changed,
            "both" => periodic || changed,
            other => {
                return Err(MpError::internal(format!(
                    "unknown frame-selection mode '{other}'"
                )))
            }
        };
        if selected {
            self.last_selected = Some(frame.clone());
            let out = p.clone();
            ctx.output(0, out);
        }
        Ok(ProcessOutcome::Continue)
    }
}

/// Image transform: resize / normalize (the pre-inference adapter).
/// Options: `out_width`, `out_height` (required), `scale` (1.0),
/// `offset` (0.0) applied as `v * scale + offset`.
pub struct ImageTransform {
    ow: usize,
    oh: usize,
    scale: f32,
    offset: f32,
}

impl Calculator for ImageTransform {
    fn open(&mut self, ctx: &mut CalculatorContext) -> MpResult<()> {
        let o = ctx.options();
        self.ow = o.int_or("out_width", 32) as usize;
        self.oh = o.int_or("out_height", 32) as usize;
        self.scale = o.float_or("scale", 1.0) as f32;
        self.offset = o.float_or("offset", 0.0) as f32;
        Ok(())
    }

    fn process(&mut self, ctx: &mut CalculatorContext) -> MpResult<ProcessOutcome> {
        let p = ctx.input(0);
        if p.is_empty() {
            return Ok(ProcessOutcome::Continue);
        }
        let frame = p.get::<ImageFrame>()?;
        let mut resized = frame.resized(self.ow, self.oh);
        if self.scale != 1.0 || self.offset != 0.0 {
            let data: Vec<f32> = resized
                .data
                .iter()
                .map(|v| v * self.scale + self.offset)
                .collect();
            resized = ImageFrame::new(self.ow, self.oh, frame.channels, data);
        }
        ctx.output_now(0, resized);
        Ok(ProcessOutcome::Continue)
    }
}

/// Template-matching detector (§6.1: "a heavy NN-based object detector
/// may be swapped out with a light template matching detector, and the
/// rest of the graph can stay unchanged"). Slides a bright-box score
/// over a coarse grid — classical CV, no model artifact needed.
///
/// Options: `grid` (default 8), `min_score` (default 0.5),
/// `box_size` (default 0.15, normalized).
pub struct TemplateMatchDetector {
    grid: usize,
    min_score: f32,
    box_size: f32,
}

impl Calculator for TemplateMatchDetector {
    fn open(&mut self, ctx: &mut CalculatorContext) -> MpResult<()> {
        let o = ctx.options();
        self.grid = o.int_or("grid", 8).max(2) as usize;
        self.min_score = o.float_or("min_score", 0.5) as f32;
        self.box_size = o.float_or("box_size", 0.15) as f32;
        Ok(())
    }

    fn process(&mut self, ctx: &mut CalculatorContext) -> MpResult<ProcessOutcome> {
        let p = ctx.input(0);
        if p.is_empty() {
            return Ok(ProcessOutcome::Continue);
        }
        let frame = p.get::<ImageFrame>()?;
        let g = self.grid;
        let bg = frame.mean();
        let mut dets: Detections = Vec::new();
        for gy in 0..g {
            for gx in 0..g {
                // cell mean brightness vs global mean = template score
                let x0 = gx * frame.width / g;
                let y0 = gy * frame.height / g;
                let x1 = ((gx + 1) * frame.width / g).max(x0 + 1);
                let y1 = ((gy + 1) * frame.height / g).max(y0 + 1);
                let mut sum = 0.0f32;
                for y in y0..y1 {
                    for x in x0..x1 {
                        sum += frame.at(x, y, 0);
                    }
                }
                let mean = sum / ((x1 - x0) * (y1 - y0)) as f32;
                let score = (mean - bg).clamp(0.0, 1.0);
                if score > self.min_score {
                    let cx = (gx as f32 + 0.5) / g as f32;
                    let cy = (gy as f32 + 0.5) / g as f32;
                    dets.push(crate::perception::Detection::new(
                        crate::perception::Rect::new(
                            cx - self.box_size / 2.0,
                            cy - self.box_size / 2.0,
                            self.box_size,
                            self.box_size,
                        )
                        .clamped(),
                        score,
                        0,
                    ));
                }
            }
        }
        let dets = crate::perception::types::non_max_suppression(dets, 0.3);
        ctx.output_now(0, dets);
        Ok(ProcessOutcome::Continue)
    }
}

pub fn register(r: &CalculatorRegistry) {
    r.register_fn(
        "SyntheticVideoSourceCalculator",
        |node| {
            let mut c = Contract::new().output("FRAME", PacketType::of::<ImageFrame>());
            if node.output_count_with_tag("GT") > 0 {
                c = c.output("GT", PacketType::of::<Detections>());
            }
            Ok(c)
        },
        |_| {
            Ok(Box::new(SyntheticVideoSource {
                world: None,
                emitted: 0,
                total: 0,
                period_us: 33_333,
                realtime: false,
                started: None,
            }))
        },
    );
    r.register_fn(
        "FrameSelectionCalculator",
        |_| {
            // timestamp offset 0: dropped frames still settle the output
            // stream so downstream joins (e.g. the detection merger)
            // don't stall between selections (§4.1.2 footnote 6).
            Ok(Contract::new()
                .input("FRAME", PacketType::of::<ImageFrame>())
                .output("FRAME", PacketType::of::<ImageFrame>())
                .with_timestamp_offset(0))
        },
        |_| {
            Ok(Box::new(FrameSelection {
                mode: String::new(),
                period: 5,
                threshold: 0.05,
                seen: 0,
                last_selected: None,
            }))
        },
    );
    r.register_fn(
        "ImageTransformCalculator",
        |_| {
            Ok(Contract::new()
                .input("", PacketType::of::<ImageFrame>())
                .output("", PacketType::of::<ImageFrame>())
                .with_timestamp_offset(0))
        },
        |_| {
            Ok(Box::new(ImageTransform {
                ow: 32,
                oh: 32,
                scale: 1.0,
                offset: 0.0,
            }))
        },
    );
    r.register_fn(
        "TemplateMatchDetectorCalculator",
        |_| {
            Ok(Contract::new()
                .input("FRAME", PacketType::of::<ImageFrame>())
                .output("DETECTIONS", PacketType::of::<Detections>())
                .with_timestamp_offset(0))
        },
        |_| {
            Ok(Box::new(TemplateMatchDetector {
                grid: 8,
                min_score: 0.5,
                box_size: 0.15,
            }))
        },
    );
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::perception::types::iou;

    fn ctx_harness() -> crate::calculator::Options {
        crate::calculator::Options::new()
    }

    // Direct unit tests of calculator logic via a minimal harness are in
    // rust/tests/perception_calculators.rs (they need graph plumbing);
    // here we test the pure pieces.

    #[test]
    fn template_detector_finds_bright_boxes() {
        let mut world = SyntheticWorld::new(64, 64, 2, 9).with_noise(0.0);
        world.step();
        let frame = world.render();
        let gt = world.ground_truth();

        // run the detector core manually
        let mut det = TemplateMatchDetector {
            grid: 8,
            min_score: 0.2,
            box_size: 0.2,
        };
        let _ = &mut det;
        // score via the same path the calculator uses: emulate process
        // with an inline copy of its scan (kept in sync by the e2e test).
        let g = det.grid;
        let bg = frame.mean();
        let mut found = Vec::new();
        for gy in 0..g {
            for gx in 0..g {
                let x0 = gx * frame.width / g;
                let y0 = gy * frame.height / g;
                let x1 = ((gx + 1) * frame.width / g).max(x0 + 1);
                let y1 = ((gy + 1) * frame.height / g).max(y0 + 1);
                let mut sum = 0.0;
                for y in y0..y1 {
                    for x in x0..x1 {
                        sum += frame.at(x, y, 0);
                    }
                }
                let mean = sum / ((x1 - x0) * (y1 - y0)) as f32;
                if (mean - bg).clamp(0.0, 1.0) > det.min_score {
                    found.push((gx, gy));
                }
            }
        }
        // at least one grid cell fires inside each GT box
        for d in &gt {
            let (cx, cy) = d.bbox.center();
            let cell = ((cx * g as f32) as usize, (cy * g as f32) as usize);
            assert!(
                found.iter().any(|&(x, y)| {
                    (x as i32 - cell.0 as i32).abs() <= 1 && (y as i32 - cell.1 as i32).abs() <= 1
                }),
                "no activation near GT {cell:?}: {found:?}"
            );
        }
        let _ = ctx_harness();
        let _ = iou; // referenced to keep the import meaningful
    }
}
