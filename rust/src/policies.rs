//! Input policies (§4.1.3): how a node's input streams are coordinated
//! into input sets.
//!
//! Synchronization is handled **locally on each node** using the policy
//! its contract declares. The default policy provides deterministic
//! synchronization: packets with equal timestamps are processed
//! together, input sets ascend strictly in timestamp, nothing is
//! dropped, and the node becomes ready as early as the guarantees allow.

use crate::packet::Packet;
use crate::stream::{Frontier, InputStreamQueue};
use crate::timestamp::{Timestamp, TimestampBound};

/// Result of a readiness query (§4.1.1: a readiness function determines
/// whether a node is ready to run).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Readiness {
    /// No valid input set can be formed yet.
    NotReady,
    /// An input set at this timestamp is ready for Process().
    Ready(Timestamp),
    /// All input streams are exhausted: the node should Close().
    Closed,
}

/// An input policy: pure logic over the node's input queues. The
/// scheduler owns the queues; policies only inspect and extract.
pub trait InputPolicy: Send {
    /// Is an input set ready, and at which timestamp?
    fn readiness(&self, queues: &[InputStreamQueue]) -> Readiness;

    /// Extract the input set at `ts` (one slot per port; empty packets
    /// for ports with no data at `ts` — paper footnote 7).
    fn take_input_set(&mut self, queues: &mut [InputStreamQueue], ts: Timestamp) -> Vec<Packet>;
}

/// The settled frontier of one stream for synchronization purposes: the
/// timestamp of the queued front packet, or the bound if empty.
fn frontier_ts(q: &InputStreamQueue) -> Timestamp {
    match q.frontier() {
        Frontier::Packet(ts) => ts,
        Frontier::EmptyUntil(b) => b.0,
    }
}

/// Conservative bound on the node's *next possible input-set timestamp*:
/// the minimum over streams of the settled frontier. With a declared
/// timestamp offset `k`, the node's outputs are therefore settled below
/// `frontier + k`; the scheduler uses this for automatic output-bound
/// propagation (§4.1.2 footnote 6).
pub fn output_bound_hint(queues: &[InputStreamQueue], offset: i64) -> TimestampBound {
    let mut min = Timestamp::DONE;
    for q in queues {
        let f = frontier_ts(q);
        if f < min {
            min = f;
        }
    }
    TimestampBound(min.add_offset(offset))
}

// ---------------------------------------------------------------------
// Default policy
// ---------------------------------------------------------------------

/// The default deterministic policy (§4.1.3): a node is ready iff there
/// is a timestamp settled across all input streams that carries a packet
/// on at least one stream.
#[derive(Debug, Default)]
pub struct DefaultPolicy;

impl InputPolicy for DefaultPolicy {
    fn readiness(&self, queues: &[InputStreamQueue]) -> Readiness {
        readiness_of_set(queues, &(0..queues.len()).collect::<Vec<_>>())
    }

    fn take_input_set(&mut self, queues: &mut [InputStreamQueue], ts: Timestamp) -> Vec<Packet> {
        queues
            .iter_mut()
            .map(|q| q.pop_at(ts).unwrap_or_else(Packet::empty))
            .collect()
    }
}

/// Default-policy readiness restricted to a subset of ports (shared with
/// SyncSetsPolicy).
fn readiness_of_set(queues: &[InputStreamQueue], ports: &[usize]) -> Readiness {
    if ports.is_empty() {
        return Readiness::NotReady;
    }
    if ports.iter().all(|&i| queues[i].is_exhausted()) {
        return Readiness::Closed;
    }
    // T = min front-packet timestamp over non-empty queues in the set.
    let mut t: Option<Timestamp> = None;
    for &i in ports {
        if let Some(f) = queues[i].front_timestamp() {
            t = Some(match t {
                Some(cur) if cur <= f => cur,
                _ => f,
            });
        }
    }
    let Some(t) = t else {
        return Readiness::NotReady; // no packets anywhere yet
    };
    // T must be settled on every stream in the set. Streams with a queued
    // packet are settled at T automatically (front >= T and monotonicity
    // settles everything below front); empty streams need bound > T.
    for &i in ports {
        if queues[i].is_empty() && !queues[i].bound().is_settled(t) {
            return Readiness::NotReady;
        }
    }
    Readiness::Ready(t)
}

// ---------------------------------------------------------------------
// Immediate policy
// ---------------------------------------------------------------------

/// Deliver each packet as soon as it arrives (§4.1.3: "a node can choose
/// to receive all inputs immediately, sacrificing several of the
/// guarantees"). Used by flow-control nodes that must react quickly
/// (§4.1.4). Input sets contain exactly one packet, delivered in
/// **arrival order** across all input streams (not timestamp order —
/// that is the whole point: the node reacts to what is happening *now*).
#[derive(Debug, Default)]
pub struct ImmediatePolicy;

impl ImmediatePolicy {
    /// Stream holding the earliest-arrived front packet.
    fn earliest_arrival(queues: &[InputStreamQueue]) -> Option<usize> {
        let mut best: Option<(u64, usize)> = None;
        for (i, q) in queues.iter().enumerate() {
            if let Some(seq) = q.front_seq() {
                best = match best {
                    Some((bseq, _)) if bseq <= seq => best,
                    _ => Some((seq, i)),
                };
            }
        }
        best.map(|(_, i)| i)
    }
}

impl InputPolicy for ImmediatePolicy {
    fn readiness(&self, queues: &[InputStreamQueue]) -> Readiness {
        if queues.iter().all(|q| q.is_exhausted()) {
            return Readiness::Closed;
        }
        match Self::earliest_arrival(queues) {
            Some(i) => Readiness::Ready(queues[i].front_timestamp().unwrap()),
            None => Readiness::NotReady,
        }
    }

    fn take_input_set(&mut self, queues: &mut [InputStreamQueue], ts: Timestamp) -> Vec<Packet> {
        // Pop the single earliest-arrived packet; all other slots stay
        // empty. `ts` is advisory (the readiness answer): we re-derive
        // the stream to stay consistent under concurrent arrivals.
        let mut set: Vec<Packet> = (0..queues.len()).map(|_| Packet::empty()).collect();
        if let Some(i) = Self::earliest_arrival(queues) {
            let _ = ts;
            set[i] = queues[i].pop_front().unwrap();
        }
        set
    }
}

// ---------------------------------------------------------------------
// Sync-sets policy
// ---------------------------------------------------------------------

/// Timestamp synchronization enforced *within* each declared set of
/// inputs but not across sets (§4.1.3, last paragraph).
#[derive(Debug)]
pub struct SyncSetsPolicy {
    sets: Vec<Vec<usize>>,
}

impl SyncSetsPolicy {
    /// `sets` partitions (a subset of) the port indices. Ports not in
    /// any set form an implicit singleton set each.
    pub fn new(mut sets: Vec<Vec<usize>>, num_ports: usize) -> SyncSetsPolicy {
        let mut covered = vec![false; num_ports];
        for s in &sets {
            for &i in s {
                covered[i] = true;
            }
        }
        for (i, c) in covered.iter().enumerate() {
            if !c {
                sets.push(vec![i]);
            }
        }
        SyncSetsPolicy { sets }
    }

    /// The ready set with the earliest timestamp (ties -> lowest index),
    /// for deterministic extraction.
    fn best_ready(&self, queues: &[InputStreamQueue]) -> Option<(usize, Timestamp)> {
        let mut best: Option<(usize, Timestamp)> = None;
        for (si, ports) in self.sets.iter().enumerate() {
            if let Readiness::Ready(t) = readiness_of_set(queues, ports) {
                best = match best {
                    Some((_, bt)) if bt <= t => best,
                    _ => Some((si, t)),
                };
            }
        }
        best
    }
}

impl InputPolicy for SyncSetsPolicy {
    fn readiness(&self, queues: &[InputStreamQueue]) -> Readiness {
        if queues.iter().all(|q| q.is_exhausted()) {
            return Readiness::Closed;
        }
        self.best_ready(queues)
            .map_or(Readiness::NotReady, |(_, t)| Readiness::Ready(t))
    }

    fn take_input_set(&mut self, queues: &mut [InputStreamQueue], ts: Timestamp) -> Vec<Packet> {
        let mut set: Vec<Packet> = (0..queues.len()).map(|_| Packet::empty()).collect();
        if let Some((si, t)) = self.best_ready(queues) {
            if t == ts {
                for &i in &self.sets[si] {
                    if let Some(p) = queues[i].pop_at(t) {
                        set[i] = p;
                    }
                }
            }
        }
        set
    }
}

/// Build the policy object a contract asks for.
pub fn make_policy(
    kind: crate::calculator::InputPolicyKind,
    sync_sets: &[Vec<usize>],
    num_ports: usize,
) -> Box<dyn InputPolicy> {
    use crate::calculator::InputPolicyKind::*;
    match kind {
        Default => Box::new(DefaultPolicy),
        Immediate => Box::new(ImmediatePolicy),
        SyncSets => Box::new(SyncSetsPolicy::new(sync_sets.to_vec(), num_ports)),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn q(name: &str) -> InputStreamQueue {
        InputStreamQueue::new(name)
    }

    fn push(qu: &mut InputStreamQueue, ts: i64) {
        qu.push(Packet::new(ts, Timestamp::new(ts))).unwrap();
    }

    /// The exact Figure-2 scenario from the paper: FOO has packets at
    /// {10, 20}, BAR at {10, 30}. Sets at 10 (both) and 20 (FOO only)
    /// are ready; 30 must wait because FOO is unsettled past 20.
    #[test]
    fn figure2_default_policy() {
        let mut queues = vec![q("FOO"), q("BAR")];
        push(&mut queues[0], 10);
        push(&mut queues[0], 20);
        push(&mut queues[1], 10);
        push(&mut queues[1], 30);

        let mut p = DefaultPolicy;
        assert_eq!(p.readiness(&queues), Readiness::Ready(Timestamp::new(10)));
        let set = p.take_input_set(&mut queues, Timestamp::new(10));
        assert!(!set[0].is_empty() && !set[1].is_empty());

        assert_eq!(p.readiness(&queues), Readiness::Ready(Timestamp::new(20)));
        let set = p.take_input_set(&mut queues, Timestamp::new(20));
        assert!(!set[0].is_empty());
        assert!(set[1].is_empty(), "BAR has no packet at 20 (footnote 7)");

        // 30 is not ready: FOO's state past 20 is unknown.
        assert_eq!(p.readiness(&queues), Readiness::NotReady);

        // "if FOO sends a packet with timestamp 25, it will have to be
        // processed before 30" (§4.1.3).
        push(&mut queues[0], 25);
        assert_eq!(p.readiness(&queues), Readiness::Ready(Timestamp::new(25)));
        p.take_input_set(&mut queues, Timestamp::new(25));

        // Now closing FOO settles everything: 30 becomes ready.
        queues[0].close();
        assert_eq!(p.readiness(&queues), Readiness::Ready(Timestamp::new(30)));
        p.take_input_set(&mut queues, Timestamp::new(30));

        queues[1].close();
        assert_eq!(p.readiness(&queues), Readiness::Closed);
    }

    #[test]
    fn default_policy_bound_advance_settles_without_packet() {
        // Footnote 6: an explicit tighter bound lets downstream settle
        // sooner.
        let mut queues = vec![q("A"), q("B")];
        push(&mut queues[0], 10);
        assert_eq!(DefaultPolicy.readiness(&queues), Readiness::NotReady);
        queues[1].advance_bound(TimestampBound(Timestamp::new(11)));
        assert_eq!(
            DefaultPolicy.readiness(&queues),
            Readiness::Ready(Timestamp::new(10))
        );
    }

    #[test]
    fn default_policy_single_stream() {
        let mut queues = vec![q("A")];
        assert_eq!(DefaultPolicy.readiness(&queues), Readiness::NotReady);
        push(&mut queues[0], 5);
        assert_eq!(
            DefaultPolicy.readiness(&queues),
            Readiness::Ready(Timestamp::new(5))
        );
    }

    #[test]
    fn default_policy_closed_only_when_exhausted() {
        let mut queues = vec![q("A")];
        push(&mut queues[0], 5);
        queues[0].close();
        // Still a packet to drain: Ready, not Closed.
        assert_eq!(
            DefaultPolicy.readiness(&queues),
            Readiness::Ready(Timestamp::new(5))
        );
        DefaultPolicy.take_input_set(&mut queues, Timestamp::new(5));
        assert_eq!(DefaultPolicy.readiness(&queues), Readiness::Closed);
    }

    #[test]
    fn default_policy_strictly_ascending_sets() {
        // Guarantee 2 of §4.1.3.
        let mut queues = vec![q("A"), q("B")];
        for t in [1, 3, 5] {
            push(&mut queues[0], t);
        }
        for t in [2, 3, 6] {
            push(&mut queues[1], t);
        }
        queues[0].close();
        queues[1].close();
        let mut p = DefaultPolicy;
        let mut last = Timestamp::UNSTARTED;
        let mut count = 0;
        while let Readiness::Ready(t) = p.readiness(&queues) {
            assert!(t > last, "sets must strictly ascend");
            last = t;
            let set = p.take_input_set(&mut queues, t);
            assert!(set.iter().any(|pk| !pk.is_empty()));
            count += 1;
        }
        // timestamps {1,2,3,5,6}: 5 distinct sets, none dropped.
        assert_eq!(count, 5);
    }

    #[test]
    fn immediate_policy_delivers_in_arrival_order() {
        let mut queues = vec![q("A"), q("B")];
        // A@10 arrives first (seq 0), B@5 second (seq 1): arrival order
        // wins over timestamp order — the flow-limiter semantics.
        queues[0]
            .push_seq(Packet::new(10i64, Timestamp::new(10)), 0)
            .unwrap();
        queues[1]
            .push_seq(Packet::new(5i64, Timestamp::new(5)), 1)
            .unwrap();
        let mut p = ImmediatePolicy;
        assert_eq!(p.readiness(&queues), Readiness::Ready(Timestamp::new(10)));
        let set = p.take_input_set(&mut queues, Timestamp::new(10));
        assert!(!set[0].is_empty() && set[1].is_empty());
        assert_eq!(p.readiness(&queues), Readiness::Ready(Timestamp::new(5)));
        let set = p.take_input_set(&mut queues, Timestamp::new(5));
        assert!(set[0].is_empty() && !set[1].is_empty());
    }

    #[test]
    fn immediate_policy_closed() {
        let mut queues = vec![q("A")];
        queues[0].close();
        assert_eq!(ImmediatePolicy.readiness(&queues), Readiness::Closed);
    }

    #[test]
    fn sync_sets_independent_alignment() {
        // Ports {0,1} form a set; port 2 is independent.
        let mut queues = vec![q("A"), q("B"), q("C")];
        push(&mut queues[2], 50);
        let mut p = SyncSetsPolicy::new(vec![vec![0, 1]], 3);
        // C alone is ready at 50 even though A/B have nothing.
        assert_eq!(p.readiness(&queues), Readiness::Ready(Timestamp::new(50)));
        let set = p.take_input_set(&mut queues, Timestamp::new(50));
        assert!(set[2].is_empty() == false);
        assert!(set[0].is_empty() && set[1].is_empty());

        // The {A,B} set still follows default-policy alignment.
        push(&mut queues[0], 10);
        assert_eq!(p.readiness(&queues), Readiness::NotReady);
        push(&mut queues[1], 10);
        assert_eq!(p.readiness(&queues), Readiness::Ready(Timestamp::new(10)));
        let set = p.take_input_set(&mut queues, Timestamp::new(10));
        assert!(!set[0].is_empty() && !set[1].is_empty());
    }

    #[test]
    fn sync_sets_uncovered_ports_get_singletons() {
        let p = SyncSetsPolicy::new(vec![vec![0]], 3);
        assert_eq!(p.sets.len(), 3);
    }

    #[test]
    fn output_bound_hint_min_frontier() {
        let mut queues = vec![q("A"), q("B")];
        push(&mut queues[0], 10);
        queues[1].advance_bound(TimestampBound(Timestamp::new(7)));
        // min(front=10, bound=7) = 7; offset 0 -> bound 7.
        assert_eq!(
            output_bound_hint(&queues, 0),
            TimestampBound(Timestamp::new(7))
        );
        assert_eq!(
            output_bound_hint(&queues, 3),
            TimestampBound(Timestamp::new(10))
        );
    }

    #[test]
    fn output_bound_hint_done_when_all_closed() {
        let mut queues = vec![q("A")];
        queues[0].close();
        assert!(output_bound_hint(&queues, 0).is_done());
    }
}
