//! Calculator registry (§3.4: "each calculator included in a program is
//! registered with the framework so that the graph configuration can
//! reference it by name").

use std::collections::HashMap;
use std::sync::{Arc, OnceLock, RwLock};

use crate::calculator::{Calculator, Contract};
use crate::error::{MpError, MpResult};
use crate::graph::config::NodeConfig;

/// Factory for one calculator type: the static `GetContract()` plus
/// object construction. The contract may depend on the node config
/// (variadic calculators such as Mux size their port lists from the
/// number of connected streams).
pub trait CalculatorFactory: Send + Sync {
    /// `GetContract()`: declare expected inputs/outputs for this node.
    fn contract(&self, node: &NodeConfig) -> MpResult<Contract>;
    /// Construct a fresh calculator object for one graph run (§3.4: the
    /// calculator object is destroyed when the graph finishes).
    fn create(&self, node: &NodeConfig) -> MpResult<Box<dyn Calculator>>;
}

/// A factory built from two closures — the common case.
pub struct FnFactory {
    contract_fn: Box<dyn Fn(&NodeConfig) -> MpResult<Contract> + Send + Sync>,
    create_fn: Box<dyn Fn(&NodeConfig) -> MpResult<Box<dyn Calculator>> + Send + Sync>,
}

impl FnFactory {
    pub fn new(
        contract_fn: impl Fn(&NodeConfig) -> MpResult<Contract> + Send + Sync + 'static,
        create_fn: impl Fn(&NodeConfig) -> MpResult<Box<dyn Calculator>> + Send + Sync + 'static,
    ) -> FnFactory {
        FnFactory {
            contract_fn: Box::new(contract_fn),
            create_fn: Box::new(create_fn),
        }
    }
}

impl CalculatorFactory for FnFactory {
    fn contract(&self, node: &NodeConfig) -> MpResult<Contract> {
        (self.contract_fn)(node)
    }

    fn create(&self, node: &NodeConfig) -> MpResult<Box<dyn Calculator>> {
        (self.create_fn)(node)
    }
}

/// Name → factory map. A process-global instance is available through
/// [`CalculatorRegistry::global`]; graphs may also be built against a
/// private registry (hermetic tests).
#[derive(Default)]
pub struct CalculatorRegistry {
    map: RwLock<HashMap<String, Arc<dyn CalculatorFactory>>>,
}

impl CalculatorRegistry {
    pub fn new() -> CalculatorRegistry {
        CalculatorRegistry::default()
    }

    /// The process-global registry, pre-populated with every built-in
    /// calculator (the "collection of re-usable components" the paper
    /// ships).
    pub fn global() -> &'static CalculatorRegistry {
        static GLOBAL: OnceLock<CalculatorRegistry> = OnceLock::new();
        GLOBAL.get_or_init(|| {
            let r = CalculatorRegistry::new();
            crate::calculators::register_builtins(&r);
            r
        })
    }

    /// Register a factory under `name`. Re-registration replaces the
    /// previous factory (useful for tests swapping implementations).
    pub fn register(&self, name: &str, factory: Arc<dyn CalculatorFactory>) {
        self.map.write().unwrap().insert(name.to_string(), factory);
    }

    /// Register from a pair of closures.
    pub fn register_fn(
        &self,
        name: &str,
        contract_fn: impl Fn(&NodeConfig) -> MpResult<Contract> + Send + Sync + 'static,
        create_fn: impl Fn(&NodeConfig) -> MpResult<Box<dyn Calculator>> + Send + Sync + 'static,
    ) {
        self.register(name, Arc::new(FnFactory::new(contract_fn, create_fn)));
    }

    /// Look up a factory.
    pub fn get(&self, name: &str) -> MpResult<Arc<dyn CalculatorFactory>> {
        self.map
            .read()
            .unwrap()
            .get(name)
            .cloned()
            .ok_or_else(|| MpError::UnknownCalculator(name.to_string()))
    }

    /// Is `name` registered?
    pub fn contains(&self, name: &str) -> bool {
        self.map.read().unwrap().contains_key(name)
    }

    /// All registered names (sorted; diagnostics / CLI listing).
    pub fn names(&self) -> Vec<String> {
        let mut v: Vec<String> = self.map.read().unwrap().keys().cloned().collect();
        v.sort();
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::calculator::{CalculatorContext, ProcessOutcome};
    use crate::packet::PacketType;

    struct Nop;

    impl Calculator for Nop {
        fn process(&mut self, _ctx: &mut CalculatorContext) -> MpResult<ProcessOutcome> {
            Ok(ProcessOutcome::Continue)
        }
    }

    #[test]
    fn register_and_lookup() {
        let r = CalculatorRegistry::new();
        r.register_fn(
            "Nop",
            |_| Ok(Contract::new().input("IN", PacketType::Any)),
            |_| Ok(Box::new(Nop)),
        );
        assert!(r.contains("Nop"));
        let f = r.get("Nop").unwrap();
        let node = NodeConfig::new("Nop");
        let c = f.contract(&node).unwrap();
        assert_eq!(c.inputs.len(), 1);
        let _calc = f.create(&node).unwrap();
    }

    #[test]
    fn unknown_name_is_error() {
        let r = CalculatorRegistry::new();
        assert!(matches!(
            r.get("Missing"),
            Err(MpError::UnknownCalculator(_))
        ));
    }

    #[test]
    fn contract_can_depend_on_node_config() {
        // Variadic contract: one input port per connected stream.
        let r = CalculatorRegistry::new();
        r.register_fn(
            "Mux",
            |node| {
                Ok(Contract::new().input_repeated(
                    "IN",
                    PacketType::Any,
                    node.input_count_with_tag("IN"),
                ))
            },
            |_| Ok(Box::new(Nop)),
        );
        let mut node = NodeConfig::new("Mux");
        for name in ["a", "b", "c"] {
            node.inputs
                .push(crate::graph::config::StreamBinding::tagged("IN", name));
        }
        let c = r.get("Mux").unwrap().contract(&node).unwrap();
        assert_eq!(c.inputs.len(), 3);
    }

    #[test]
    fn global_registry_has_builtins() {
        let g = CalculatorRegistry::global();
        assert!(g.contains("PassThroughCalculator"));
        assert!(!g.names().is_empty());
    }

    #[test]
    fn reregistration_replaces() {
        let r = CalculatorRegistry::new();
        r.register_fn("X", |_| Ok(Contract::new()), |_| Ok(Box::new(Nop)));
        r.register_fn(
            "X",
            |_| Ok(Contract::new().output("O", PacketType::Any)),
            |_| Ok(Box::new(Nop)),
        );
        let c = r.get("X").unwrap().contract(&NodeConfig::new("X")).unwrap();
        assert_eq!(c.outputs.len(), 1);
    }
}
