//! Timestamps and timestamp bounds (§3.1, §4.1.2).
//!
//! A MediaPipe timestamp is a monotonically increasing value within a
//! stream; its *primary* role is to serve as a synchronization key. The
//! value range is `i64` microseconds plus a handful of special values at
//! the extremes, mirroring upstream MediaPipe:
//!
//! ```text
//!   Unset < Unstarted < PreStream < Min <= normal values <= Max < PostStream < Done
//! ```
//!
//! * `PreStream` — a packet delivered before the time-series starts
//!   (e.g. a header); only valid as the first packet of a stream.
//! * `PostStream` — a packet delivered after the series ends (e.g. a
//!   whole-stream aggregate); must be the only packet or follow Max.
//! * `Done` — the bound value signalling "no more packets, ever".
//!
//! Each stream carries a [`TimestampBound`]: the lowest timestamp a new
//! packet on the stream may still have. When a packet with timestamp `T`
//! arrives, the bound advances to `T + 1` (§4.1.2), which is how
//! downstream nodes learn that timestamps `<= T` are *settled*.

use std::fmt;

const UNSET: i64 = i64::MIN;
const UNSTARTED: i64 = i64::MIN + 1;
const PRESTREAM: i64 = i64::MIN + 2;
const MIN: i64 = i64::MIN + 3;
const MAX: i64 = i64::MAX - 2;
const POSTSTREAM: i64 = i64::MAX - 1;
const DONE: i64 = i64::MAX;

/// A packet timestamp: i64 microseconds with reserved special values.
#[derive(Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct Timestamp(i64);

impl Timestamp {
    /// Timestamp of a default-constructed (unset) packet.
    pub const UNSET: Timestamp = Timestamp(UNSET);
    /// Before any packet: initial bound value of every stream.
    pub const UNSTARTED: Timestamp = Timestamp(UNSTARTED);
    /// Header packets: delivered before the time series proper.
    pub const PRESTREAM: Timestamp = Timestamp(PRESTREAM);
    /// Smallest normal timestamp.
    pub const MIN: Timestamp = Timestamp(MIN);
    /// Largest normal timestamp.
    pub const MAX: Timestamp = Timestamp(MAX);
    /// Aggregate packets: delivered after the time series ends.
    pub const POSTSTREAM: Timestamp = Timestamp(POSTSTREAM);
    /// Bound value meaning the stream is closed: no packet will ever
    /// arrive. Not a valid packet timestamp.
    pub const DONE: Timestamp = Timestamp(DONE);

    /// A normal timestamp from a microsecond value. Panics if the value
    /// collides with a reserved special value.
    pub fn new(micros: i64) -> Timestamp {
        assert!(
            (MIN..=MAX).contains(&micros),
            "timestamp {micros} outside the normal range"
        );
        Timestamp(micros)
    }

    /// Construct from a raw value that may be special (used by config
    /// parsing and trace import).
    pub fn from_raw(raw: i64) -> Timestamp {
        Timestamp(raw)
    }

    /// The raw i64, including special values.
    pub fn raw(self) -> i64 {
        self.0
    }

    /// Microsecond value; panics on special timestamps.
    pub fn micros(self) -> i64 {
        assert!(self.is_normal(), "micros() on special timestamp {self:?}");
        self.0
    }

    /// True for values in `[MIN, MAX]` (i.e. an actual instant).
    pub fn is_normal(self) -> bool {
        (MIN..=MAX).contains(&self.0)
    }

    /// True if this timestamp may appear on a packet in a stream
    /// (normal, PreStream or PostStream).
    pub fn is_allowed_in_stream(self) -> bool {
        self.is_normal() || self == Timestamp::PRESTREAM || self == Timestamp::POSTSTREAM
    }

    /// The smallest timestamp a following packet may carry — the bound
    /// value after observing a packet at `self` (§4.1.2): normally
    /// `self + 1`; `PreStream` is followed by `Min`; `Max` and
    /// `PostStream` are followed by `Done`.
    pub fn next_allowed_in_stream(self) -> Timestamp {
        match self.0 {
            PRESTREAM => Timestamp::MIN,
            MAX | POSTSTREAM => Timestamp::DONE,
            v if self.is_normal() => Timestamp(v + 1),
            _ => panic!("next_allowed_in_stream on {self:?}"),
        }
    }

    /// Successor value used for bound arithmetic (saturating at DONE).
    pub fn successor(self) -> Timestamp {
        if self.0 >= DONE {
            Timestamp::DONE
        } else {
            Timestamp(self.0 + 1)
        }
    }

    /// `self + offset` µs, clamped to the normal range. Used by
    /// timestamp-offset bound propagation.
    pub fn add_offset(self, offset: i64) -> Timestamp {
        if !self.is_normal() {
            return self;
        }
        Timestamp(self.0.saturating_add(offset).clamp(MIN, MAX))
    }
}

impl fmt::Debug for Timestamp {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self.0 {
            UNSET => write!(f, "Timestamp::Unset"),
            UNSTARTED => write!(f, "Timestamp::Unstarted"),
            PRESTREAM => write!(f, "Timestamp::PreStream"),
            POSTSTREAM => write!(f, "Timestamp::PostStream"),
            DONE => write!(f, "Timestamp::Done"),
            v => write!(f, "Timestamp({v})"),
        }
    }
}

impl fmt::Display for Timestamp {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{self:?}")
    }
}

/// The lowest possible timestamp allowed for a *new* packet on a stream
/// (§4.1.2). A timestamp `T` is **settled** for the stream once
/// `T < bound`: either a packet at `T` already arrived, or it is certain
/// none ever will.
#[derive(Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct TimestampBound(pub Timestamp);

impl TimestampBound {
    /// The initial bound of every stream: nothing has happened yet.
    pub const UNSTARTED: TimestampBound = TimestampBound(Timestamp::UNSTARTED);
    /// The final bound: the stream is closed.
    pub const DONE: TimestampBound = TimestampBound(Timestamp::DONE);

    /// Is `ts` settled under this bound?
    pub fn is_settled(self, ts: Timestamp) -> bool {
        ts < self.0
    }

    /// Is the stream closed?
    pub fn is_done(self) -> bool {
        self.0 == Timestamp::DONE
    }

    /// Bound after a packet at `ts` arrives.
    pub fn after_packet(ts: Timestamp) -> TimestampBound {
        TimestampBound(ts.next_allowed_in_stream())
    }

    /// Monotonic merge: a bound can only move forward. Returns whether
    /// it actually advanced.
    pub fn advance_to(&mut self, other: TimestampBound) -> bool {
        if other.0 > self.0 {
            self.0 = other.0;
            true
        } else {
            false
        }
    }
}

impl fmt::Debug for TimestampBound {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "Bound({:?})", self.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn special_value_ordering() {
        assert!(Timestamp::UNSET < Timestamp::UNSTARTED);
        assert!(Timestamp::UNSTARTED < Timestamp::PRESTREAM);
        assert!(Timestamp::PRESTREAM < Timestamp::MIN);
        assert!(Timestamp::MIN < Timestamp::new(0));
        assert!(Timestamp::new(0) < Timestamp::MAX);
        assert!(Timestamp::MAX < Timestamp::POSTSTREAM);
        assert!(Timestamp::POSTSTREAM < Timestamp::DONE);
    }

    #[test]
    fn normal_range_classification() {
        assert!(Timestamp::new(42).is_normal());
        assert!(Timestamp::MIN.is_normal());
        assert!(Timestamp::MAX.is_normal());
        assert!(!Timestamp::PRESTREAM.is_normal());
        assert!(!Timestamp::DONE.is_normal());
    }

    #[test]
    fn allowed_in_stream() {
        assert!(Timestamp::new(0).is_allowed_in_stream());
        assert!(Timestamp::PRESTREAM.is_allowed_in_stream());
        assert!(Timestamp::POSTSTREAM.is_allowed_in_stream());
        assert!(!Timestamp::UNSET.is_allowed_in_stream());
        assert!(!Timestamp::DONE.is_allowed_in_stream());
        assert!(!Timestamp::UNSTARTED.is_allowed_in_stream());
    }

    #[test]
    fn next_allowed_semantics() {
        // §4.1.2: packet at T advances the bound to T+1.
        assert_eq!(
            Timestamp::new(10).next_allowed_in_stream(),
            Timestamp::new(11)
        );
        // PreStream is followed by the series proper.
        assert_eq!(Timestamp::PRESTREAM.next_allowed_in_stream(), Timestamp::MIN);
        // Max / PostStream end the stream.
        assert_eq!(Timestamp::MAX.next_allowed_in_stream(), Timestamp::DONE);
        assert_eq!(
            Timestamp::POSTSTREAM.next_allowed_in_stream(),
            Timestamp::DONE
        );
    }

    #[test]
    #[should_panic]
    fn next_allowed_rejects_unset() {
        Timestamp::UNSET.next_allowed_in_stream();
    }

    #[test]
    #[should_panic]
    fn new_rejects_special_collision() {
        Timestamp::new(i64::MAX);
    }

    #[test]
    fn settled_definition() {
        // "a timestamp is settled for a stream once it is lower than the
        // timestamp bound" (§4.1.3).
        let bound = TimestampBound::after_packet(Timestamp::new(20));
        assert!(bound.is_settled(Timestamp::new(20)));
        assert!(bound.is_settled(Timestamp::new(10)));
        assert!(!bound.is_settled(Timestamp::new(21)));
        assert!(!bound.is_settled(Timestamp::new(30)));
    }

    #[test]
    fn bound_is_monotonic() {
        let mut b = TimestampBound::UNSTARTED;
        assert!(b.advance_to(TimestampBound::after_packet(Timestamp::new(5))));
        // Moving backwards is a no-op.
        assert!(!b.advance_to(TimestampBound::after_packet(Timestamp::new(3))));
        assert_eq!(b, TimestampBound(Timestamp::new(6)));
        assert!(b.advance_to(TimestampBound::DONE));
        assert!(b.is_done());
    }

    #[test]
    fn add_offset_clamps() {
        assert_eq!(Timestamp::new(10).add_offset(5), Timestamp::new(15));
        assert_eq!(Timestamp::MAX.add_offset(10), Timestamp::MAX);
        // Special values pass through untouched.
        assert_eq!(Timestamp::PRESTREAM.add_offset(10), Timestamp::PRESTREAM);
    }

    #[test]
    fn successor_saturates() {
        assert_eq!(Timestamp::DONE.successor(), Timestamp::DONE);
        assert_eq!(Timestamp::new(1).successor(), Timestamp::new(2));
    }
}
