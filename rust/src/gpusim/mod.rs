//! GpuContextSim: a simulated multi-context GPU (§4.2 substrate).
//!
//! The paper's GPU support rests on three mechanisms we reproduce
//! faithfully enough to test and benchmark without hardware
//! (DESIGN.md §Substitutions):
//!
//! 1. **one dedicated thread per GL context**, each building a *serial*
//!    command queue executed asynchronously ("one GL context corresponds
//!    to one sequential command queue");
//! 2. **sync fences** for cross-context ordering: CPU-side thread
//!    synchronization is NOT enough — command *execution* is reordered
//!    across queues unless a wait-on-fence is inserted into the
//!    consumer's queue. We simulate that hazard: a read command that
//!    executes before the producer's fence signals observes the
//!    buffer's *stale* contents (and the simulator counts it);
//! 3. **buffer recycling** gated on consumer fences ("before passing it
//!    to a new producer for writing, the framework waits for all
//!    existing consumers to finish reading").

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

/// A sync fence: signalled once by the producer queue, waitable by any
/// other queue (GL fence-sync semantics).
#[derive(Clone, Default)]
pub struct Fence {
    inner: Arc<FenceInner>,
}

#[derive(Default)]
struct FenceInner {
    signalled: Mutex<bool>,
    cv: Condvar,
}

impl Fence {
    pub fn new() -> Fence {
        Fence::default()
    }

    pub fn signal(&self) {
        let mut s = self.inner.signalled.lock().unwrap();
        *s = true;
        self.inner.cv.notify_all();
    }

    pub fn wait(&self) {
        let mut s = self.inner.signalled.lock().unwrap();
        while !*s {
            s = self.inner.cv.wait(s).unwrap();
        }
    }

    pub fn is_signalled(&self) -> bool {
        *self.inner.signalled.lock().unwrap()
    }
}

/// A shared GPU buffer: a version counter stands in for the texels.
/// Writers bump the version when the *write command executes*; readers
/// snapshot it. A consumer that runs before the producer's write
/// completed sees the old version — the §4.2 data race.
pub struct SimBuffer {
    pub id: u64,
    version: AtomicU64,
    /// Set while a write command is mid-flight (models partial writes).
    writing: AtomicBool,
}

impl SimBuffer {
    pub fn new(id: u64) -> Arc<SimBuffer> {
        Arc::new(SimBuffer {
            id,
            version: AtomicU64::new(0),
            writing: AtomicBool::new(false),
        })
    }

    pub fn version(&self) -> u64 {
        self.version.load(Ordering::Acquire)
    }
}

/// One command in a context's serial queue.
pub enum Command {
    /// Execute `work` after simulating `gpu_time` of execution; a write
    /// bumps the buffer version at the END of the simulated time.
    Write {
        buffer: Arc<SimBuffer>,
        gpu_time: Duration,
    },
    /// Read the buffer; reports (buffer id, observed version, torn) to
    /// the callback. `torn` is true when the read overlapped a write.
    Read {
        buffer: Arc<SimBuffer>,
        gpu_time: Duration,
        on_value: Box<dyn FnOnce(u64, bool) + Send>,
    },
    /// Insert a fence signal (producer side: "write complete").
    SignalFence(Fence),
    /// Wait for a fence signalled by another queue (consumer side).
    WaitFence(Fence),
    /// Generic timed work (e.g. rendering cost).
    Work { gpu_time: Duration },
    /// Run arbitrary host code from the queue thread (test hooks).
    Callback(Box<dyn FnOnce() + Send>),
}

struct ContextInner {
    queue: Mutex<VecDeque<Command>>,
    cv: Condvar,
    shutdown: AtomicBool,
    /// Commands executed (stats).
    executed: AtomicU64,
}

/// One simulated GL context: a serial command queue with a dedicated
/// execution thread.
pub struct GpuContext {
    pub name: String,
    inner: Arc<ContextInner>,
    worker: Option<JoinHandle<()>>,
}

impl GpuContext {
    pub fn new(name: &str) -> GpuContext {
        let inner = Arc::new(ContextInner {
            queue: Mutex::new(VecDeque::new()),
            cv: Condvar::new(),
            shutdown: AtomicBool::new(false),
            executed: AtomicU64::new(0),
        });
        let i2 = Arc::clone(&inner);
        let worker = std::thread::Builder::new()
            .name(format!("gpusim-{name}"))
            .spawn(move || loop {
                let cmd = {
                    let mut q = i2.queue.lock().unwrap();
                    loop {
                        if let Some(c) = q.pop_front() {
                            break Some(c);
                        }
                        if i2.shutdown.load(Ordering::Acquire) {
                            break None;
                        }
                        q = i2.cv.wait(q).unwrap();
                    }
                };
                let Some(cmd) = cmd else { return };
                // Count up-front: finish() observers must see a stable
                // count the moment their callback runs.
                i2.executed.fetch_add(1, Ordering::Relaxed);
                match cmd {
                    Command::Write { buffer, gpu_time } => {
                        buffer.writing.store(true, Ordering::Release);
                        spin_for(gpu_time);
                        buffer.version.fetch_add(1, Ordering::AcqRel);
                        buffer.writing.store(false, Ordering::Release);
                    }
                    Command::Read {
                        buffer,
                        gpu_time,
                        on_value,
                    } => {
                        let torn = buffer.writing.load(Ordering::Acquire);
                        let v = buffer.version();
                        spin_for(gpu_time);
                        on_value(v, torn);
                    }
                    Command::SignalFence(f) => f.signal(),
                    Command::WaitFence(f) => f.wait(),
                    Command::Work { gpu_time } => spin_for(gpu_time),
                    Command::Callback(f) => f(),
                }
            })
            .expect("spawn gpusim worker");
        GpuContext {
            name: name.to_string(),
            inner,
            worker: Some(worker),
        }
    }

    /// Append a command to this context's serial queue (returns
    /// immediately — execution is asynchronous, like glFlush-less GL).
    pub fn submit(&self, cmd: Command) {
        let mut q = self.inner.queue.lock().unwrap();
        q.push_back(cmd);
        drop(q);
        self.inner.cv.notify_one();
    }

    /// Block until the queue is empty (glFinish).
    pub fn finish(&self) {
        let done = Arc::new((Mutex::new(false), Condvar::new()));
        let d2 = Arc::clone(&done);
        self.submit(Command::Callback(Box::new(move || {
            let (m, cv) = &*d2;
            *m.lock().unwrap() = true;
            cv.notify_all();
        })));
        let (m, cv) = &*done;
        let mut g = m.lock().unwrap();
        while !*g {
            g = cv.wait(g).unwrap();
        }
    }

    pub fn executed(&self) -> u64 {
        self.inner.executed.load(Ordering::Relaxed)
    }
}

impl Drop for GpuContext {
    fn drop(&mut self) {
        self.inner.shutdown.store(true, Ordering::Release);
        self.inner.cv.notify_all();
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

/// Simulated GPU execution time. Sleep-based, NOT spin-based: the
/// simulated GPU is a *different device* — its "execution" must not
/// consume host CPU, and queue overlap must be observable even on a
/// single-core host.
fn spin_for(d: Duration) {
    if !d.is_zero() {
        std::thread::sleep(d);
    }
}

/// The framework-managed buffer pool (§4.2.2 last paragraph): tracks a
/// producer fence and consumer fences per buffer, and recycles only
/// after all consumers signalled.
pub struct BufferPool {
    next_id: AtomicU64,
    free: Mutex<Vec<PooledBuffer>>,
}

struct PooledBuffer {
    buffer: Arc<SimBuffer>,
    consumer_fences: Vec<Fence>,
}

/// A buffer checked out of the pool with its bookkeeping.
pub struct BufferLease {
    pub buffer: Arc<SimBuffer>,
    /// "write complete" — signalled by the producer queue.
    pub producer_fence: Fence,
}

impl Default for BufferPool {
    fn default() -> Self {
        BufferPool::new()
    }
}

impl BufferPool {
    pub fn new() -> BufferPool {
        BufferPool {
            next_id: AtomicU64::new(1),
            free: Mutex::new(Vec::new()),
        }
    }

    /// Acquire a buffer for a new producer. If recycling, WAITS for all
    /// previous consumers' fences first (the §4.2 recycle rule).
    pub fn acquire(&self) -> BufferLease {
        let recycled = self.free.lock().unwrap().pop();
        let buffer = match recycled {
            Some(pb) => {
                for f in &pb.consumer_fences {
                    f.wait();
                }
                pb.buffer
            }
            None => SimBuffer::new(self.next_id.fetch_add(1, Ordering::Relaxed)),
        };
        BufferLease {
            buffer,
            producer_fence: Fence::new(),
        }
    }

    /// Return a buffer with the consumer fences that must signal before
    /// the next producer may write it.
    pub fn release(&self, buffer: Arc<SimBuffer>, consumer_fences: Vec<Fence>) {
        self.free.lock().unwrap().push(PooledBuffer {
            buffer,
            consumer_fences,
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc;

    const MS: Duration = Duration::from_millis(1);

    #[test]
    fn commands_execute_serially_within_context() {
        let ctx = GpuContext::new("a");
        let buf = SimBuffer::new(1);
        for _ in 0..3 {
            ctx.submit(Command::Write {
                buffer: Arc::clone(&buf),
                gpu_time: MS,
            });
        }
        ctx.finish();
        assert_eq!(buf.version(), 3);
        assert_eq!(ctx.executed(), 4); // 3 writes + finish callback
    }

    #[test]
    fn cross_context_without_fence_races() {
        // Producer writes slowly; consumer reads immediately: without a
        // fence the read observes the stale version.
        let prod = GpuContext::new("prod");
        let cons = GpuContext::new("cons");
        let buf = SimBuffer::new(1);
        let (tx, rx) = mpsc::channel();
        prod.submit(Command::Write {
            buffer: Arc::clone(&buf),
            gpu_time: Duration::from_millis(20),
        });
        cons.submit(Command::Read {
            buffer: Arc::clone(&buf),
            gpu_time: MS,
            on_value: Box::new(move |v, torn| {
                let _ = tx.send((v, torn));
            }),
        });
        let (v, torn) = rx.recv().unwrap();
        assert!(v == 0 || torn, "read must observe staleness: v={v} torn={torn}");
        prod.finish();
        cons.finish();
    }

    #[test]
    fn fence_orders_cross_context_access() {
        let prod = GpuContext::new("prod");
        let cons = GpuContext::new("cons");
        let buf = SimBuffer::new(1);
        let fence = Fence::new();
        let (tx, rx) = mpsc::channel();
        prod.submit(Command::Write {
            buffer: Arc::clone(&buf),
            gpu_time: Duration::from_millis(20),
        });
        prod.submit(Command::SignalFence(fence.clone()));
        cons.submit(Command::WaitFence(fence));
        cons.submit(Command::Read {
            buffer: Arc::clone(&buf),
            gpu_time: MS,
            on_value: Box::new(move |v, torn| {
                let _ = tx.send((v, torn));
            }),
        });
        let (v, torn) = rx.recv().unwrap();
        assert_eq!(v, 1, "fence guarantees the write is visible");
        assert!(!torn);
        prod.finish();
        cons.finish();
    }

    #[test]
    fn fences_do_not_serialize_unrelated_work() {
        // Two contexts doing independent work overlap in wall time.
        let a = GpuContext::new("a");
        let b = GpuContext::new("b");
        let t0 = std::time::Instant::now();
        for _ in 0..10 {
            a.submit(Command::Work {
                gpu_time: Duration::from_millis(2),
            });
            b.submit(Command::Work {
                gpu_time: Duration::from_millis(2),
            });
        }
        a.finish();
        b.finish();
        let elapsed = t0.elapsed();
        // serial would be >= 40ms; parallel ~20ms + overhead.
        assert!(
            elapsed < Duration::from_millis(38),
            "contexts did not overlap: {elapsed:?}"
        );
    }

    #[test]
    fn pool_recycle_waits_for_consumers() {
        let pool = BufferPool::new();
        let lease = pool.acquire();
        let id = lease.buffer.id;
        let consumer_fence = Fence::new();
        pool.release(Arc::clone(&lease.buffer), vec![consumer_fence.clone()]);
        // Re-acquire from another thread: must block until the consumer
        // fence signals.
        let pool = Arc::new(pool);
        let p2 = Arc::clone(&pool);
        let (tx, rx) = mpsc::channel();
        let h = std::thread::spawn(move || {
            let lease2 = p2.acquire();
            let _ = tx.send(lease2.buffer.id);
        });
        assert!(
            rx.recv_timeout(Duration::from_millis(30)).is_err(),
            "acquire returned before the consumer finished"
        );
        consumer_fence.signal();
        let got = rx.recv_timeout(Duration::from_secs(2)).unwrap();
        assert_eq!(got, id, "recycled the same buffer");
        h.join().unwrap();
    }

    #[test]
    fn fence_is_sticky() {
        let f = Fence::new();
        assert!(!f.is_signalled());
        f.signal();
        f.wait(); // returns immediately
        assert!(f.is_signalled());
    }
}
